//! Search strategies over a [`DesignSpace`].
//!
//! All three strategies (exhaustive grid, seeded random sampling,
//! seeded hill-climbing) funnel every candidate through one memoized,
//! cache-backed, `par_map`-parallelized evaluator, and report the
//! evaluated set in canonical grid order — which makes the whole search
//! bit-identical whether it ran on one thread (`MEDUSA_THREADS=1`) or
//! many, and whether the cache was cold or warm.

use crate::config::SimBackend;
use crate::explore::cache::{point_key, ExploreCache};
use crate::explore::pareto::{pareto_frontier, FrontierEntry};
use crate::explore::space::{evaluate_impl, DesignSpace, ExplorePoint, Metrics};
use crate::serving::ServingSpec;
use crate::util::{par_map_with, Prng};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};

/// How to walk the space.
#[derive(Clone, Debug)]
pub enum Strategy {
    /// Evaluate every grid point.
    Grid,
    /// Evaluate a deterministic seeded sample of `samples` grid points.
    Random { samples: usize },
    /// `restarts` seeded hill-climbs of up to `steps` moves each,
    /// maximizing bandwidth per (LUT + FF). Every point the climbs
    /// visit (including rejected neighbors) lands in the evaluated set.
    HillClimb { restarts: usize, steps: usize },
}

impl Strategy {
    pub fn label(&self) -> String {
        match self {
            Strategy::Grid => "grid".to_string(),
            Strategy::Random { samples } => format!("random({samples})"),
            Strategy::HillClimb { restarts, steps } => format!("hill({restarts}x{steps})"),
        }
    }
}

/// The outcome of one search run.
pub struct SearchResult {
    /// Every evaluated point with its metrics, in canonical grid order.
    pub evaluated: Vec<(ExplorePoint, Metrics)>,
    /// The Pareto frontier of the evaluated set.
    pub frontier: Vec<FrontierEntry>,
    /// Evaluations answered from the on-disk cache.
    pub cache_hits: usize,
    /// Evaluations actually computed (simulated) this run.
    pub computed: usize,
    /// Per-point campaign telemetry, aligned with `evaluated` (same
    /// canonical grid order): whether the point was answered from the
    /// cache and its host-side evaluation time. Host time only — never
    /// part of cache keys or metric comparisons.
    pub timings: Vec<crate::obs::PointTiming>,
}

/// The hill-climb objective: achieved bandwidth per unit of LUT + FF.
/// Infeasible or unverified points are never climbed onto.
fn score(m: &Metrics) -> f64 {
    if !m.feasible() || !m.verified {
        return f64::NEG_INFINITY;
    }
    m.gbps() / (m.resources.lut + m.resources.ff).max(1) as f64
}

/// Memoized, cache-backed batch evaluator.
struct Evaluator<'a> {
    probe: &'a str,
    serving: Option<&'a ServingSpec>,
    all: &'a [ExplorePoint],
    workers: usize,
    backend: SimBackend,
    memo: BTreeMap<usize, Metrics>,
    timings: BTreeMap<usize, crate::obs::PointTiming>,
    cache_hits: usize,
    computed: usize,
}

impl<'a> Evaluator<'a> {
    fn key(&self, i: usize) -> u64 {
        point_key(&self.all[i], self.probe, self.backend.payload, self.serving)
    }

    fn eval_batch(&mut self, idxs: &[usize], cache: &mut Option<&mut ExploreCache>) {
        let mut todo: Vec<usize> = Vec::new();
        for &i in idxs {
            if self.memo.contains_key(&i) || todo.contains(&i) {
                continue;
            }
            if let Some(c) = cache.as_deref() {
                if let Some(m) = c.get(self.key(i)) {
                    self.memo.insert(i, m);
                    self.timings.insert(
                        i,
                        crate::obs::PointTiming { index: i, cache_hit: true, eval_s: 0.0 },
                    );
                    self.cache_hits += 1;
                    continue;
                }
            }
            todo.push(i);
        }
        if todo.is_empty() {
            return;
        }
        let probe = self.probe;
        let serving = self.serving;
        let backend = self.backend;
        let points: Vec<ExplorePoint> = todo.iter().map(|&i| self.all[i]).collect();
        // Wall-clock per evaluation rides alongside the metrics. It is
        // campaign telemetry only: the metrics themselves (and the
        // cache entries keyed off them) are untouched, so search
        // results stay bit-identical with or without a consumer of
        // `timings`.
        let metrics = par_map_with(self.workers, &points, move |p| {
            let t0 = std::time::Instant::now();
            let m = evaluate_impl(p, probe, backend, serving);
            (m, t0.elapsed().as_secs_f64())
        });
        for (&i, (m, eval_s)) in todo.iter().zip(metrics) {
            let key = self.key(i);
            if let Some(c) = cache.as_deref_mut() {
                c.insert(key, m);
            }
            self.memo.insert(i, m);
            self.timings
                .insert(i, crate::obs::PointTiming { index: i, cache_hit: false, eval_s });
            self.computed += 1;
        }
    }
}

/// Run a search with the fast (stats-exact) evaluation backend — the
/// explorer default. See [`run_search_impl`].
pub fn run_search(
    space: &DesignSpace,
    strategy: &Strategy,
    seed: u64,
    workers: usize,
    cache: Option<&mut ExploreCache>,
) -> Result<SearchResult> {
    run_search_impl(space, strategy, seed, workers, cache, SimBackend::fast())
}

/// Run a search under an explicit backend.
#[deprecated(
    since = "0.7.0",
    note = "use run::RunOptions::new().threads(n).backend(b).run_search(..)"
)]
pub fn run_search_with(
    space: &DesignSpace,
    strategy: &Strategy,
    seed: u64,
    workers: usize,
    cache: Option<&mut ExploreCache>,
    backend: SimBackend,
) -> Result<SearchResult> {
    run_search_impl(space, strategy, seed, workers, cache, backend)
}

/// Run a search. `workers` is the parallel width for evaluation batches
/// (pass `util::parallel::max_threads()` to honour `MEDUSA_THREADS`);
/// results are bit-identical for any value — and for any `backend`,
/// since evaluation metrics are backend-invariant. A cache, when given,
/// is both consulted and extended (and saved before returning); entries
/// are keyed per payload mode so a full-payload sweep never silently
/// reuses an elided (unverifying) evaluation — and per serving spec, so
/// a serving-probe sweep never reuses a closed-loop entry (whose
/// `serving_p99` is 0) — see [`point_key`].
pub(crate) fn run_search_impl(
    space: &DesignSpace,
    strategy: &Strategy,
    seed: u64,
    workers: usize,
    mut cache: Option<&mut ExploreCache>,
    backend: SimBackend,
) -> Result<SearchResult> {
    let all = space.points();
    let mut ev = Evaluator {
        probe: &space.probe,
        serving: space.serving.as_ref(),
        all: &all,
        workers,
        backend,
        memo: BTreeMap::new(),
        timings: BTreeMap::new(),
        cache_hits: 0,
        computed: 0,
    };
    match strategy {
        Strategy::Grid => {
            let idxs: Vec<usize> = (0..all.len()).collect();
            ev.eval_batch(&idxs, &mut cache);
        }
        Strategy::Random { samples } => {
            let mut idxs: Vec<usize> = (0..all.len()).collect();
            Prng::new(seed).shuffle(&mut idxs);
            idxs.truncate((*samples).min(all.len()));
            idxs.sort_unstable();
            ev.eval_batch(&idxs, &mut cache);
        }
        Strategy::HillClimb { restarts, steps } => {
            let coords = coordinates(space, &all);
            let mut prng = Prng::new(seed);
            for _ in 0..*restarts {
                let mut cur = prng.below(all.len() as u64) as usize;
                ev.eval_batch(&[cur], &mut cache);
                for _ in 0..*steps {
                    let neigh = coords.neighbors(cur);
                    ev.eval_batch(&neigh, &mut cache);
                    // Move to the best strictly improving neighbor;
                    // fixed neighbor order makes ties deterministic.
                    let cur_score = score(&ev.memo[&cur]);
                    let best = neigh
                        .iter()
                        .map(|&i| (score(&ev.memo[&i]), i))
                        .fold(None::<(f64, usize)>, |acc, (s, i)| match acc {
                            Some((bs, bi)) if bs >= s => Some((bs, bi)),
                            _ => Some((s, i)),
                        });
                    match best {
                        Some((s, i)) if s > cur_score => cur = i,
                        _ => break, // local optimum
                    }
                }
            }
        }
    }
    if let Some(c) = cache.as_deref_mut() {
        c.save()?;
    }
    let evaluated: Vec<(ExplorePoint, Metrics)> =
        ev.memo.iter().map(|(&i, &m)| (all[i], m)).collect();
    let frontier = pareto_frontier(&evaluated);
    let timings: Vec<crate::obs::PointTiming> = ev.timings.into_values().collect();
    Ok(SearchResult {
        evaluated,
        frontier,
        cache_hits: ev.cache_hits,
        computed: ev.computed,
        timings,
    })
}

/// Grid coordinates (port idx, width-mult idx, depth idx, design rank)
/// for hill-climb neighborhood moves.
struct Coordinates {
    of: Vec<[usize; 4]>,
    index: HashMap<[usize; 4], usize>,
}

impl Coordinates {
    /// Indices one step away along each axis (present in the grid).
    fn neighbors(&self, idx: usize) -> Vec<usize> {
        let c = self.of[idx];
        let mut out = Vec::with_capacity(8);
        for axis in 0..4 {
            for delta in [-1isize, 1] {
                let mut n = c;
                let v = n[axis] as isize + delta;
                if v < 0 {
                    continue;
                }
                n[axis] = v as usize;
                // Moves across geometry cells can land on design ranks
                // that do not exist there (family sizes differ) or on
                // width cells collapsed by the 1024-bit cap; the map
                // simply has no entry for those.
                if let Some(&i) = self.index.get(&n) {
                    out.push(i);
                }
            }
        }
        out
    }
}

/// Each grid point's coordinates, from the space's single canonical
/// enumeration ([`DesignSpace::points_with_coords`]).
fn coordinates(space: &DesignSpace, all: &[ExplorePoint]) -> Coordinates {
    let pts = space.points_with_coords();
    assert_eq!(pts.len(), all.len(), "coordinate enumeration diverged from the evaluated grid");
    let mut of = Vec::with_capacity(pts.len());
    let mut index = HashMap::with_capacity(pts.len());
    for (i, (_, coord)) in pts.into_iter().enumerate() {
        of.push(coord);
        index.insert(coord, i);
    }
    Coordinates { of, index }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_space() -> DesignSpace {
        DesignSpace {
            ports: vec![4, 8],
            width_mults: vec![1],
            depths: vec![8],
            max_burst: 4,
            probe: "gemm-mlp".to_string(),
            serving: None,
        }
    }

    #[test]
    fn grid_search_covers_every_point_and_is_thread_invariant() {
        let space = tiny_space();
        let seq = run_search(&space, &Strategy::Grid, 1, 1, None).unwrap();
        let par = run_search(&space, &Strategy::Grid, 1, 4, None).unwrap();
        assert_eq!(seq.evaluated.len(), space.points().len());
        assert_eq!(seq.evaluated, par.evaluated, "worker count changed search results");
        assert_eq!(seq.frontier.len(), par.frontier.len());
        assert!(!seq.frontier.is_empty());
        assert_eq!(seq.cache_hits, 0);
        assert_eq!(seq.computed, seq.evaluated.len());
    }

    #[test]
    fn random_strategy_is_seed_deterministic() {
        let space = tiny_space();
        let a = run_search(&space, &Strategy::Random { samples: 3 }, 42, 2, None).unwrap();
        let b = run_search(&space, &Strategy::Random { samples: 3 }, 42, 1, None).unwrap();
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.evaluated.len(), 3);
        // Different seeds must be able to pick different samples (any
        // one seed may collide by chance on a tiny grid; three cannot).
        let some_differ = (43..46).any(|s| {
            run_search(&space, &Strategy::Random { samples: 3 }, s, 2, None).unwrap().evaluated
                != a.evaluated
        });
        assert!(some_differ, "random sampling ignored the seed");
    }

    #[test]
    fn hill_climb_is_deterministic_and_improves() {
        let space = tiny_space();
        let strat = Strategy::HillClimb { restarts: 2, steps: 4 };
        let a = run_search(&space, &strat, 7, 2, None).unwrap();
        let b = run_search(&space, &strat, 7, 1, None).unwrap();
        assert_eq!(a.evaluated, b.evaluated);
        assert!(!a.evaluated.is_empty());
        // The best score the climb saw is at least the best start score
        // (it only ever moves uphill).
        let best = a.evaluated.iter().map(|(_, m)| score(m)).fold(f64::NEG_INFINITY, f64::max);
        assert!(best.is_finite(), "at least one visited point must be feasible");
    }

    #[test]
    fn serving_space_populates_tail_latency_metrics() {
        let mut space = tiny_space();
        space.serving = Some(ServingSpec {
            seed: 3,
            requests: 2,
            mean_gap: 1_000,
            max_batch: 1,
            max_wait: 200,
            ..ServingSpec::default()
        });
        let r = run_search(&space, &Strategy::Random { samples: 2 }, 1, 2, None).unwrap();
        assert_eq!(r.evaluated.len(), 2);
        assert!(
            r.evaluated.iter().all(|(_, m)| !m.feasible() || m.serving_p99 > 0),
            "every feasible point under a serving probe must measure a tail latency"
        );
    }

    #[test]
    fn timings_align_with_the_evaluated_set_and_count_hits() {
        let space = tiny_space();
        let dir = std::env::temp_dir().join(format!("medusa-timings-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.tsv");
        let mut cache = ExploreCache::open(&path);
        let cold = run_search(&space, &Strategy::Grid, 1, 2, Some(&mut cache)).unwrap();
        assert_eq!(cold.timings.len(), cold.evaluated.len());
        assert!(cold.timings.iter().all(|t| !t.cache_hit), "cold run cannot hit the cache");
        assert_eq!(cold.timings.iter().filter(|t| !t.cache_hit).count(), cold.computed);
        // Timings are in canonical grid order, like `evaluated`.
        assert!(cold.timings.windows(2).all(|w| w[0].index < w[1].index));
        let mut cache = ExploreCache::open(&path);
        let warm = run_search(&space, &Strategy::Grid, 1, 2, Some(&mut cache)).unwrap();
        assert!(warm.timings.iter().all(|t| t.cache_hit), "warm run must hit on every point");
        assert_eq!(warm.timings.iter().filter(|t| t.cache_hit).count(), warm.cache_hits);
        assert_eq!(cold.evaluated, warm.evaluated, "telemetry must not perturb results");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn coordinates_mirror_the_grid_enumeration() {
        let space = DesignSpace::default_grid();
        let all = space.points();
        let coords = coordinates(&space, &all);
        assert_eq!(coords.of.len(), all.len());
        // Neighbors are symmetric: if j is a neighbor of i, i is one of j.
        for i in (0..all.len()).step_by(17) {
            for j in coords.neighbors(i) {
                assert!(coords.neighbors(j).contains(&i), "asymmetric neighbors {i} {j}");
            }
        }
    }
}
