//! On-disk result cache for design-space sweeps.
//!
//! Keyed by a stable FNV-1a hash of a design point's full identity (the
//! parseable design spec, every geometry field, the layer-processor
//! size, the channel depths, the probe network, the evaluation payload
//! mode — see [`point_key`] for why — and a format/version tag that
//! invalidates entries whenever the models change). Values are the
//! exact integer [`Metrics`], so a warm sweep reproduces a cold one
//! bit-for-bit — the incremental-sweep correctness contract, locked by
//! `tests/explore_conformance.rs`.
//!
//! The format is one line per entry, written sorted by key, so cache
//! files are deterministic, diffable, and trivially inspectable:
//!
//! ```text
//! medusa-explore-cache v6
//! <key:016x> <lut> <ff> <bram18> <dsp> <fmax> <lines> <bits> <ps> <cycles> <verified> <serving_p99>
//! ```
//!
//! Unreadable or version-mismatched files are treated as empty (a cache
//! must never be able to wedge a sweep), and saving rewrites the whole
//! file atomically-enough (write + rename is overkill here: the cache is
//! a pure accelerator whose loss costs only recomputation).

use crate::config::PayloadMode;
use crate::explore::space::{ExplorePoint, Metrics};
use crate::fpga::Resources;
use crate::serving::ServingSpec;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Bump on any change to the resource/timing models, the probe scenario
/// semantics, the evaluation backend, or the entry layout — stale
/// entries must never be served. v7: serving specs grew the overload
/// controls (queue_cap/overload/deadline/retries/backoff, PR 10) —
/// they change what a serving probe measures, and older binaries
/// cannot parse headers carrying them. v6: the hierarchical family
/// joined the grid (PR 8) — the enumeration order behind every cached
/// sweep changed, and older binaries cannot parse `hierarchical:*`
/// specs, so pre-hierarchy caches are discarded wholesale. v5: entries
/// grew a `serving_p99` column and keys a serving-spec component
/// (PR 7).
pub const CACHE_VERSION: u64 = 7;

const HEADER: &str = "medusa-explore-cache v7";

/// Stable identity hash of one (point, probe, payload-mode, serving)
/// evaluation.
///
/// The payload mode participates because `Metrics::verified` means
/// different things per mode: a full-payload evaluation golden-checks
/// the probe's data, an elided one has no data to check (vacuously
/// true). Every *numeric* metric is backend-invariant (the fast-backend
/// conformance contract), but serving an elided entry to a
/// `--payload=full` sweep would silently skip the golden verification
/// the caller explicitly asked for — so the two modes keep separate
/// entries. The serving spec participates because it changes what the
/// probe measures (`serving_p99`, and the run length itself): a
/// closed-loop entry must never answer a serving-probe sweep or vice
/// versa, and two different arrival schedules are different
/// experiments. Edge mode does NOT participate: leaping changes no
/// field, verification included.
pub fn point_key(
    point: &ExplorePoint,
    probe: &str,
    payload: PayloadMode,
    serving: Option<&ServingSpec>,
) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01b3);
    };
    mix(CACHE_VERSION);
    mix(payload as u64);
    for b in point.design.spec().bytes() {
        mix(b as u64);
    }
    mix(point.geometry.w_line as u64);
    mix(point.geometry.w_acc as u64);
    mix(point.geometry.read_ports as u64);
    mix(point.geometry.write_ports as u64);
    mix(point.geometry.max_burst as u64);
    mix(point.dpus as u64);
    mix(point.channel_depth as u64);
    for b in probe.bytes() {
        mix(b as u64);
    }
    match serving {
        None => mix(0),
        Some(s) => {
            mix(1);
            mix(s.seed);
            mix(s.requests as u64);
            mix(s.mean_gap);
            mix(s.max_batch as u64);
            mix(s.max_wait);
            mix(s.slo_cycles);
            mix(s.queue_cap as u64);
            mix(s.overload as u64);
            mix(s.deadline);
            mix(s.retries as u64);
            mix(s.backoff);
            mix(s.arrivals.len() as u64);
            for &a in &s.arrivals {
                mix(a);
            }
        }
    }
    h
}

pub struct ExploreCache {
    path: PathBuf,
    map: BTreeMap<u64, Metrics>,
    dirty: bool,
}

impl ExploreCache {
    /// Open a cache file; missing, unreadable, or version-mismatched
    /// files yield an empty cache at that path.
    pub fn open(path: impl AsRef<Path>) -> Self {
        let path = path.as_ref().to_path_buf();
        let map = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| parse(&text))
            .unwrap_or_default();
        ExploreCache { path, map, dirty: false }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, key: u64) -> Option<Metrics> {
        self.map.get(&key).copied()
    }

    pub fn insert(&mut self, key: u64, m: Metrics) {
        if self.map.insert(key, m) != Some(m) {
            self.dirty = true;
        }
    }

    /// Persist if anything changed since open/last save.
    pub fn save(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        let mut out = String::with_capacity(64 * (self.map.len() + 1));
        out.push_str(HEADER);
        out.push('\n');
        for (key, m) in &self.map {
            out.push_str(&format!(
                "{key:016x} {} {} {} {} {} {} {} {} {} {} {}\n",
                m.resources.lut,
                m.resources.ff,
                m.resources.bram18,
                m.resources.dsp,
                m.fmax_mhz,
                m.lines_moved,
                m.bits_moved,
                m.sim_ps,
                m.fabric_cycles,
                u64::from(m.verified),
                m.serving_p99,
            ));
        }
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating cache dir {}", dir.display()))?;
            }
        }
        std::fs::write(&self.path, out)
            .with_context(|| format!("writing explore cache {}", self.path.display()))?;
        self.dirty = false;
        Ok(())
    }
}

fn parse(text: &str) -> Option<BTreeMap<u64, Metrics>> {
    let mut lines = text.lines();
    if lines.next()? != HEADER {
        return None;
    }
    let mut map = BTreeMap::new();
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split_ascii_whitespace().collect();
        if f.len() != 12 {
            return None;
        }
        let key = u64::from_str_radix(f[0], 16).ok()?;
        let num = |i: usize| f[i].parse::<u64>().ok();
        map.insert(
            key,
            Metrics {
                resources: Resources {
                    lut: num(1)?,
                    ff: num(2)?,
                    bram18: num(3)?,
                    dsp: num(4)?,
                },
                fmax_mhz: num(5)? as u32,
                lines_moved: num(6)?,
                bits_moved: num(7)?,
                sim_ps: num(8)?,
                fabric_cycles: num(9)?,
                verified: num(10)? != 0,
                serving_p99: num(11)?,
            },
        );
    }
    Some(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::space::DesignSpace;

    fn sample_metrics() -> Metrics {
        Metrics {
            resources: Resources { lut: 1234, ff: 5678, bram18: 9, dsp: 512 },
            fmax_mhz: 225,
            lines_moved: 1000,
            bits_moved: 128_000,
            sim_ps: 7_777_777,
            fabric_cycles: 4321,
            verified: true,
            serving_p99: 86_000,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("medusa-cache-test-{}-{name}", std::process::id()))
    }

    #[test]
    fn round_trips_entries_exactly() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut c = ExploreCache::open(&path);
        assert!(c.is_empty());
        c.insert(42, sample_metrics());
        c.insert(7, Metrics { verified: false, fmax_mhz: 0, ..sample_metrics() });
        c.save().unwrap();
        let c2 = ExploreCache::open(&path);
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.get(42), Some(sample_metrics()));
        assert_eq!(c2.get(7).unwrap().fmax_mhz, 0);
        assert_eq!(c2.get(99), None);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupt_or_foreign_files_read_as_empty() {
        let path = tmp("corrupt");
        std::fs::write(&path, "not a cache\n123 nonsense\n").unwrap();
        assert!(ExploreCache::open(&path).is_empty());
        std::fs::write(&path, format!("{HEADER}\nzzzz bad line\n")).unwrap();
        assert!(ExploreCache::open(&path).is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn save_is_idempotent_and_deterministic() {
        let path = tmp("determ");
        let _ = std::fs::remove_file(&path);
        let mut c = ExploreCache::open(&path);
        c.insert(3, sample_metrics());
        c.insert(1, sample_metrics());
        c.save().unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        // Re-inserting identical values does not dirty the cache.
        c.insert(3, sample_metrics());
        c.save().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), first);
        // Sorted by key regardless of insertion order.
        let keys: Vec<&str> =
            first.lines().skip(1).map(|l| l.split_whitespace().next().unwrap()).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn keys_distinguish_every_grid_point() {
        let pts = DesignSpace::default_grid().points();
        let mut keys: Vec<u64> =
            pts.iter().map(|p| point_key(p, "gemm-mlp", PayloadMode::Elided, None)).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), pts.len(), "cache keys must be collision-free on the grid");
        // The probe participates in the key.
        assert_ne!(
            point_key(&pts[0], "gemm-mlp", PayloadMode::Elided, None),
            point_key(&pts[0], "tiny-vgg", PayloadMode::Elided, None)
        );
        // So does the payload mode: a full-payload sweep must never be
        // served an elided (vacuously verified) evaluation.
        assert_ne!(
            point_key(&pts[0], "gemm-mlp", PayloadMode::Elided, None),
            point_key(&pts[0], "gemm-mlp", PayloadMode::Full, None)
        );
    }

    #[test]
    fn keys_distinguish_serving_specs() {
        let pts = DesignSpace::default_grid().points();
        let spec = ServingSpec {
            seed: 3,
            requests: 4,
            mean_gap: 1_000,
            max_batch: 2,
            max_wait: 500,
            ..ServingSpec::default()
        };
        // Serving vs closed-loop: separate entries (serving_p99 differs).
        assert_ne!(
            point_key(&pts[0], "gemm-mlp", PayloadMode::Elided, None),
            point_key(&pts[0], "gemm-mlp", PayloadMode::Elided, Some(&spec))
        );
        // Two different arrival schedules are different experiments.
        let other = ServingSpec { seed: 4, ..spec.clone() };
        assert_ne!(
            point_key(&pts[0], "gemm-mlp", PayloadMode::Elided, Some(&spec)),
            point_key(&pts[0], "gemm-mlp", PayloadMode::Elided, Some(&other))
        );
        // So are two different overload policies on the same arrivals.
        let bounded = ServingSpec { queue_cap: 3, deadline: 20_000, ..spec.clone() };
        assert_ne!(
            point_key(&pts[0], "gemm-mlp", PayloadMode::Elided, Some(&spec)),
            point_key(&pts[0], "gemm-mlp", PayloadMode::Elided, Some(&bounded))
        );
    }
}
