//! The explorable design space and the evaluation of one point.
//!
//! A point is a full accelerator design: an interconnect design (the
//! baseline, Medusa, an intermediate hybrid family member, or a
//! clustered hierarchical member), a geometry, a layer-processor size,
//! and the CDC channel depths. Its
//! measured quantities come from the same models the paper evaluation
//! uses — the analytical resource roll-up, the 25 MHz P&R frequency
//! search — plus one the paper never reports: *achieved* bandwidth,
//! from running a `workload::zoo` probe network through the simulated
//! system at the searched clock.

use crate::config::{ChannelDepths, SimBackend, SystemConfig};
use crate::fpga::par::search_peak_frequency;
use crate::fpga::timing::TimingModel;
use crate::fpga::{DesignPoint, Device, Resources};
use crate::interconnect::hierarchical::HierConfig;
use crate::interconnect::hybrid::HybridConfig;
use crate::interconnect::Design;
use crate::serving::ServingSpec;
use crate::types::Geometry;
use crate::util::{ceil_log2, next_pow2};
use crate::workload::engine::run_scenario;
use crate::workload::scenario::Scenario;
use crate::workload::zoo;

/// One explorable design point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExplorePoint {
    pub design: Design,
    pub geometry: Geometry,
    /// Layer-processor size (vector dot-product units).
    pub dpus: usize,
    /// Depth of all three CDC channels (cmd / rd_line / wr_data).
    pub channel_depth: usize,
}

impl ExplorePoint {
    /// One-line identity for tables and error messages.
    pub fn label(&self) -> String {
        format!(
            "{} {}b {}p b{} d{}",
            self.design.spec(),
            self.geometry.w_line,
            self.geometry.read_ports,
            self.geometry.max_burst,
            self.channel_depth
        )
    }

    fn design_point(&self) -> DesignPoint {
        DesignPoint { design: self.design, geometry: self.geometry, dpus: self.dpus }
    }
}

/// What one evaluation measures. Everything is stored in integers (the
/// bandwidth is a bits/picoseconds *ratio*, kept as its numerator and
/// denominator) so cached results round-trip bit-exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Metrics {
    pub resources: Resources,
    /// Peak post-P&R frequency on the 25 MHz search grid; 0 = the point
    /// fails timing entirely (infeasible — never simulated).
    pub fmax_mhz: u32,
    /// Lines the probe scenario moved through the fabric.
    pub lines_moved: u64,
    /// `lines_moved x W_line` — the bandwidth numerator.
    pub bits_moved: u64,
    /// Simulated wall time of the probe run (ps) — the denominator.
    pub sim_ps: u64,
    pub fabric_cycles: u64,
    /// Golden verification of the probe run (read path + DRAM content).
    pub verified: bool,
    /// Worst per-tenant p99 serving latency (fabric cycles) when the
    /// evaluation carried a serving probe; 0 when serving is disabled
    /// (the default) or the point is infeasible. Lets the Pareto
    /// explorer rank designs by tail latency under an open-loop load,
    /// not just raw bandwidth.
    pub serving_p99: u64,
}

impl Metrics {
    pub fn feasible(&self) -> bool {
        self.fmax_mhz > 0
    }

    /// Achieved probe bandwidth in Gbit/s (display only — comparisons
    /// use the exact integer ratio, see `pareto`).
    pub fn gbps(&self) -> f64 {
        if self.sim_ps == 0 {
            0.0
        } else {
            self.bits_moved as f64 / self.sim_ps as f64 * 1000.0
        }
    }

    fn infeasible(resources: Resources) -> Metrics {
        Metrics {
            resources,
            fmax_mhz: 0,
            lines_moved: 0,
            bits_moved: 0,
            sim_ps: 0,
            fabric_cycles: 0,
            verified: false,
            serving_p99: 0,
        }
    }
}

/// The grid the explorer enumerates. Geometries follow the Fig 6 sizing
/// rule (interface width = smallest power of two covering the ports,
/// optionally doubled; DPUs scale with ports, capped at the figure's
/// 3072-DSP ceiling); each geometry carries the full design family:
/// baseline, every intermediate hybrid radix (un- and fully pipelined),
/// and Medusa.
#[derive(Clone, Debug)]
pub struct DesignSpace {
    /// Port counts (read = write), each within [4, 64].
    pub ports: Vec<usize>,
    /// Interface-width multipliers over the minimal power of two
    /// (capped at 1024 bits; duplicates after capping are dropped).
    pub width_mults: Vec<usize>,
    /// CDC channel depths to explore.
    pub depths: Vec<usize>,
    /// Burst length in lines (fixed per space; 8 keeps the probe
    /// simulations fast while exercising real burst behaviour).
    pub max_burst: usize,
    /// Zoo network driven through every feasible point.
    pub probe: String,
    /// Optional serving front-end attached to every probe run: the
    /// probe network becomes the per-request pass of an open-loop
    /// serving tenant, and `Metrics::serving_p99` reports the measured
    /// tail latency. `None` (the default) keeps the classic closed-loop
    /// probe and leaves `serving_p99` at 0.
    pub serving: Option<ServingSpec>,
}

impl DesignSpace {
    /// The default grid: 5 port counts x up to 2 widths x 2 channel
    /// depths x the full design family per geometry — 144 points, ≥ 100
    /// as the PR 4 acceptance floor requires (locked by a test).
    pub fn default_grid() -> Self {
        DesignSpace {
            ports: vec![4, 8, 16, 32, 64],
            width_mults: vec![1, 2],
            depths: vec![2, 8],
            max_burst: 8,
            probe: "gemm-mlp".to_string(),
            serving: None,
        }
    }

    /// A tiny grid for CI smoke runs (20 points, small geometries only;
    /// the 8-port geometries carry the hierarchical members).
    pub fn smoke() -> Self {
        DesignSpace {
            ports: vec![4, 8],
            width_mults: vec![1, 2],
            depths: vec![8],
            max_burst: 8,
            probe: "gemm-mlp".to_string(),
            serving: None,
        }
    }

    /// The interconnect designs explored on one geometry, in canonical
    /// order: baseline, intermediate hybrid radices ascending (each
    /// unpipelined and fully pipelined), hierarchical depths ascending
    /// (where the port count supports >= 2 clusters), Medusa. The radix
    /// endpoints are the plain designs themselves (`interconnect::hybrid`
    /// instantiates exactly these datapaths there), so listing them as
    /// hybrids too would only duplicate points.
    pub fn designs_for(geom: &Geometry) -> Vec<Design> {
        let n = geom.words_per_line();
        let mut out = vec![Design::Baseline];
        let mut r = 4usize;
        while r < n {
            for stages in [0usize, ceil_log2(r)] {
                out.push(Design::Hybrid(HybridConfig {
                    transpose_radix: r,
                    stage_pipelining: stages,
                    port_group_width: 1,
                }));
            }
            r *= 2;
        }
        // Four clusters of ports/4 each — the densest division every
        // grid port count supports; two trunk depths.
        if geom.read_ports >= 8 && geom.read_ports % 4 == 0 {
            for levels in [2usize, 3] {
                out.push(Design::Hierarchical(HierConfig {
                    levels,
                    cluster_ports: geom.read_ports / 4,
                    bypass_ports: 0,
                    trunk_mhz: 300,
                }));
            }
        }
        out.push(Design::Medusa);
        out
    }

    /// Geometry for one (ports, width multiplier) cell; `None` when the
    /// capped width duplicates a smaller multiplier.
    fn geometry(&self, ports: usize, mult: usize) -> Option<Geometry> {
        let base = next_pow2(ports * 16);
        let w_line = (base * mult).min(1024);
        if mult > 1 && w_line == base {
            return None; // cap collapsed this cell onto mult = 1
        }
        Some(Geometry {
            w_line,
            w_acc: 16,
            read_ports: ports,
            write_ports: ports,
            max_burst: self.max_burst,
        })
    }

    /// DPUs for a port count: the Fig 6 scaling rule (2 per port),
    /// capped at the figure's largest layer processor (96 DPUs = 3072
    /// DSPs) so every point fits the device.
    fn dpus(ports: usize) -> usize {
        (2 * ports).min(96)
    }

    /// Enumerate the whole grid in canonical order, pairing each point
    /// with its (port idx, width-mult idx, depth idx, design rank)
    /// coordinates — the hill-climb neighborhood basis. This is THE one
    /// enumeration loop; [`DesignSpace::points`] and the search
    /// strategies all derive from it, so the order (the determinism
    /// anchor) and the skip rules cannot drift apart.
    pub fn points_with_coords(&self) -> Vec<(ExplorePoint, [usize; 4])> {
        let mut out = Vec::new();
        for (pi, &ports) in self.ports.iter().enumerate() {
            for (mi, &mult) in self.width_mults.iter().enumerate() {
                let Some(geometry) = self.geometry(ports, mult) else { continue };
                for (di, &depth) in self.depths.iter().enumerate() {
                    for (rank, design) in Self::designs_for(&geometry).into_iter().enumerate() {
                        let point = ExplorePoint {
                            design,
                            geometry,
                            dpus: Self::dpus(ports),
                            channel_depth: depth,
                        };
                        out.push((point, [pi, mi, di, rank]));
                    }
                }
            }
        }
        out
    }

    /// The grid points alone, in canonical order.
    pub fn points(&self) -> Vec<ExplorePoint> {
        self.points_with_coords().into_iter().map(|(p, _)| p).collect()
    }
}

/// Evaluate one point with the **fast backend** (payload elision +
/// idle-edge leaping) — the explorer's default. Every `Metrics` field
/// is derived from timing and movement counters, which the fast backend
/// reproduces bit-identically (locked by
/// `tests/fast_backend_conformance.rs`), so this is a pure speedup.
pub fn evaluate(point: &ExplorePoint, probe: &str) -> Metrics {
    evaluate_impl(point, probe, SimBackend::fast(), None)
}

/// Evaluate one point under an explicit simulation backend.
#[deprecated(
    since = "0.7.0",
    note = "use run::RunOptions::new().backend(b).evaluate(point, probe)"
)]
pub fn evaluate_with(point: &ExplorePoint, probe: &str, backend: SimBackend) -> Metrics {
    evaluate_impl(point, probe, backend, None)
}

/// Evaluate one point under an explicit simulation backend and an
/// optional serving probe: resource roll-up, P&R frequency search, then
/// — for feasible points — a simulated probe run at the searched clock.
/// With a serving spec, the probe network becomes the per-request pass
/// of an open-loop serving tenant and `serving_p99` reports the worst
/// tenant tail latency. Pure and deterministic: same point + same probe
/// (+ same serving spec) → identical `Metrics`, on any thread and under
/// ANY backend (`verified` reports the golden data checks in
/// full-payload mode and is vacuously true in elided mode, where the
/// schedules themselves are the cross-checked artifact; serving
/// latencies are cycle-exact under every backend by the leap-exactness
/// argument in DESIGN.md §9).
pub(crate) fn evaluate_impl(
    point: &ExplorePoint,
    probe: &str,
    backend: SimBackend,
    serving: Option<&ServingSpec>,
) -> Metrics {
    let dp = point.design_point();
    let resources = dp.resources();
    let model = TimingModel::calibrated();
    let dev = Device::virtex7_690t();
    let fmax = search_peak_frequency(&model, &dp, &dev).peak_mhz;
    if fmax == 0 {
        return Metrics::infeasible(resources);
    }
    let cfg = SystemConfig {
        design: point.design,
        geometry: point.geometry,
        dotprod_units: point.dpus,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: Some(fmax as f64),
        ddr3_timing: false,
        rotator_stages: 0,
        channel_depths: ChannelDepths {
            cmd: point.channel_depth,
            rd_line: point.channel_depth,
            wr_data: point.channel_depth,
        },
        seed: 7,
        sim: backend,
    };
    let net = zoo::by_name(probe)
        .unwrap_or_else(|| panic!("unknown probe network {probe:?} (zoo: {:?})", zoo::names()));
    let mut sc = Scenario::single("explore-probe", cfg, net);
    if let Some(spec) = serving {
        sc.serving = spec.clone();
    }
    let out = run_scenario(&sc)
        .unwrap_or_else(|e| panic!("probe run failed on {}: {e:#}", point.label()));
    let lines: u64 = out.tenants.iter().map(|t| t.report.total_lines_moved()).sum();
    Metrics {
        resources,
        fmax_mhz: fmax,
        lines_moved: lines,
        bits_moved: lines * point.geometry.w_line as u64,
        sim_ps: out.now_ps,
        fabric_cycles: out.fabric_cycles,
        verified: out.all_verified(),
        serving_p99: out.serving.as_ref().map(|r| r.worst_p99()).unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_meets_the_hundred_point_floor() {
        let pts = DesignSpace::default_grid().points();
        assert!(pts.len() >= 100, "default grid has only {} points", pts.len());
        // Port range covers the 4–64 span.
        assert!(pts.iter().any(|p| p.geometry.read_ports == 4));
        assert!(pts.iter().any(|p| p.geometry.read_ports == 64));
        // Every geometry carries both endpoints and, where N allows,
        // intermediate hybrids.
        assert!(pts.iter().any(|p| matches!(p.design, Design::Hybrid(_))));
        for p in &pts {
            p.geometry.validate().unwrap();
            match p.design {
                Design::Hybrid(hc) => hc.validate(&p.geometry).unwrap(),
                Design::Hierarchical(hc) => hc.validate(&p.geometry).unwrap(),
                _ => {}
            }
        }
    }

    #[test]
    fn grid_points_are_unique() {
        let pts = DesignSpace::default_grid().points();
        for (i, a) in pts.iter().enumerate() {
            for b in pts.iter().skip(i + 1) {
                assert_ne!(a, b, "duplicate grid point {}", a.label());
            }
        }
    }

    #[test]
    fn smoke_grid_is_small_and_valid() {
        let pts = DesignSpace::smoke().points();
        assert!(
            (8..=32).contains(&pts.len()),
            "smoke grid should stay tiny, got {}",
            pts.len()
        );
        assert!(pts.iter().all(|p| p.geometry.read_ports <= 8));
        // The CI smoke gate must exercise the hierarchical family too.
        assert!(
            pts.iter().any(|p| matches!(p.design, Design::Hierarchical(_))),
            "smoke grid lost its hierarchical points"
        );
    }

    #[test]
    fn family_ordering_is_canonical() {
        let g = Geometry { w_line: 256, w_acc: 16, read_ports: 16, write_ports: 16, max_burst: 8 };
        let designs = DesignSpace::designs_for(&g); // N = 16
        assert_eq!(designs.first(), Some(&Design::Baseline));
        assert_eq!(designs.last(), Some(&Design::Medusa));
        // r in {4, 8} x two pipeline variants, then two trunk depths.
        assert_eq!(designs.len(), 2 + 2 * 2 + 2);
        assert!(designs[designs.len() - 3..designs.len() - 1]
            .iter()
            .all(|d| matches!(d, Design::Hierarchical(_))));
    }

    #[test]
    fn evaluate_small_point_measures_bandwidth() {
        let pt = ExplorePoint {
            design: Design::Medusa,
            geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
            dpus: 16,
            channel_depth: 8,
        };
        let m = evaluate(&pt, "gemm-mlp");
        assert!(m.feasible());
        // (`m.verified` is vacuously true under the elided default;
        // genuine golden verification of this exact point is asserted
        // by `fast_backend_metrics_equal_full_backend_metrics` below.)
        assert!(m.lines_moved > 0 && m.sim_ps > 0);
        assert!(m.gbps() > 0.0);
        assert_eq!(m.bits_moved, m.lines_moved * 128);
        assert_eq!(m.serving_p99, 0, "closed-loop probe must not report serving latency");
        // Determinism: a second evaluation is bit-identical.
        assert_eq!(evaluate(&pt, "gemm-mlp"), m);
    }

    #[test]
    fn fast_backend_metrics_equal_full_backend_metrics() {
        // THE explorer-soundness contract: the fast default must agree
        // with a full golden-verified evaluation on every field, for a
        // representative of each family.
        use crate::interconnect::hybrid::HybridConfig;
        use crate::run::RunOptions;
        let g = Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 };
        for design in [
            Design::Baseline,
            Design::Medusa,
            Design::Hybrid(HybridConfig::default()),
            Design::Hierarchical(HierConfig {
                levels: 2,
                cluster_ports: 4,
                bypass_ports: 0,
                trunk_mhz: 300,
            }),
        ] {
            let pt = ExplorePoint { design, geometry: g, dpus: 16, channel_depth: 8 };
            let full = RunOptions::new().backend(SimBackend::full()).evaluate(&pt, "gemm-mlp");
            let fast = RunOptions::new().backend(SimBackend::fast()).evaluate(&pt, "gemm-mlp");
            assert!(full.verified, "{design:?}: full probe must golden-verify");
            assert_eq!(full, fast, "{design:?}: fast backend drifted from full");
        }
    }

    #[test]
    fn serving_probe_reports_backend_invariant_tail_latency() {
        use crate::run::RunOptions;
        let pt = ExplorePoint {
            design: Design::Medusa,
            geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
            dpus: 16,
            channel_depth: 8,
        };
        let spec = ServingSpec {
            seed: 3,
            requests: 3,
            mean_gap: 2_000,
            max_batch: 1,
            max_wait: 500,
            ..ServingSpec::default()
        };
        let full = RunOptions::new()
            .backend(SimBackend::full())
            .serving(spec.clone())
            .evaluate(&pt, "gemm-mlp");
        let fast = RunOptions::new()
            .backend(SimBackend::fast())
            .serving(spec)
            .evaluate(&pt, "gemm-mlp");
        assert!(full.serving_p99 > 0, "serving probe must measure a tail latency");
        assert_eq!(full, fast, "serving metrics drifted between backends");
    }
}
