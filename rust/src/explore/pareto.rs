//! Pareto frontier over {LUT, FF, Fmax, achieved bandwidth}.
//!
//! A point dominates another when it is no worse on every objective
//! (fewer-or-equal LUTs and FFs, higher-or-equal Fmax and bandwidth)
//! and strictly better on at least one. Bandwidth is compared as the
//! exact integer ratio `bits_moved / sim_ps` via cross-multiplication —
//! no floating point anywhere in the dominance test, so the frontier is
//! bit-stable across platforms and thread counts.

use crate::explore::space::{ExplorePoint, Metrics};
use std::cmp::Ordering;

/// One non-dominated design point.
#[derive(Clone, Copy, Debug)]
pub struct FrontierEntry {
    /// Index into the evaluated slice the frontier was computed from.
    pub index: usize,
    pub point: ExplorePoint,
    pub metrics: Metrics,
}

/// Exact comparison of achieved bandwidth (bits/ps as a ratio).
pub fn cmp_bandwidth(a: &Metrics, b: &Metrics) -> Ordering {
    match (a.sim_ps, b.sim_ps) {
        (0, 0) => Ordering::Equal,
        (0, _) => Ordering::Less,
        (_, 0) => Ordering::Greater,
        (pa, pb) => {
            (a.bits_moved as u128 * pb as u128).cmp(&(b.bits_moved as u128 * pa as u128))
        }
    }
}

/// Does `a` dominate `b`?
fn dominates(a: &Metrics, b: &Metrics) -> bool {
    let bw = cmp_bandwidth(a, b);
    let no_worse = a.resources.lut <= b.resources.lut
        && a.resources.ff <= b.resources.ff
        && a.fmax_mhz >= b.fmax_mhz
        && bw != Ordering::Less;
    let strictly_better = a.resources.lut < b.resources.lut
        || a.resources.ff < b.resources.ff
        || a.fmax_mhz > b.fmax_mhz
        || bw == Ordering::Greater;
    no_worse && strictly_better
}

/// The non-dominated subset of `evaluated`, in a deterministic order
/// (ascending LUT, then FF, then the design spec string). Infeasible
/// (failed-timing) and unverified points are never frontier members —
/// a design that moves no data must not survive as "cheapest".
pub fn pareto_frontier(evaluated: &[(ExplorePoint, Metrics)]) -> Vec<FrontierEntry> {
    let candidates: Vec<usize> = evaluated
        .iter()
        .enumerate()
        .filter(|(_, (_, m))| m.feasible() && m.verified)
        .map(|(i, _)| i)
        .collect();
    let mut out: Vec<FrontierEntry> = candidates
        .iter()
        .filter(|&&i| {
            let (_, mi) = &evaluated[i];
            !candidates.iter().any(|&j| j != i && dominates(&evaluated[j].1, mi))
        })
        .map(|&i| FrontierEntry { index: i, point: evaluated[i].0, metrics: evaluated[i].1 })
        .collect();
    out.sort_by(|a, b| {
        (a.metrics.resources.lut, a.metrics.resources.ff, a.point.design.spec(), a.index).cmp(&(
            b.metrics.resources.lut,
            b.metrics.resources.ff,
            b.point.design.spec(),
            b.index,
        ))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::Resources;
    use crate::interconnect::Design;
    use crate::types::Geometry;

    fn pt() -> ExplorePoint {
        ExplorePoint {
            design: Design::Medusa,
            geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
            dpus: 16,
            channel_depth: 8,
        }
    }

    fn m(lut: u64, ff: u64, fmax: u32, bits: u64, ps: u64) -> Metrics {
        Metrics {
            resources: Resources { lut, ff, bram18: 0, dsp: 0 },
            fmax_mhz: fmax,
            lines_moved: bits / 128,
            bits_moved: bits,
            sim_ps: ps,
            fabric_cycles: 1,
            verified: true,
            serving_p99: 0,
        }
    }

    #[test]
    fn dominated_points_are_dropped() {
        let evaluated = vec![
            (pt(), m(100, 100, 200, 1000, 10)), // dominates the next
            (pt(), m(200, 200, 100, 500, 10)),
            (pt(), m(50, 300, 200, 1000, 10)), // cheaper LUT, worse FF: stays
        ];
        let f = pareto_frontier(&evaluated);
        let idxs: Vec<usize> = f.iter().map(|e| e.index).collect();
        assert_eq!(idxs.len(), 2);
        assert!(idxs.contains(&0) && idxs.contains(&2));
    }

    #[test]
    fn bandwidth_compares_exactly_not_in_floats() {
        // Equal ratios expressed with different denominators are equal.
        let a = m(1, 1, 25, 1000, 3);
        let b = m(1, 1, 25, 2000, 6);
        assert_eq!(cmp_bandwidth(&a, &b), Ordering::Equal);
        // One part per trillion apart still orders correctly.
        let c = m(1, 1, 25, 1_000_000_000_001, 3_000_000_000_000);
        let d = m(1, 1, 25, 1_000_000_000_000, 3_000_000_000_000);
        assert_eq!(cmp_bandwidth(&c, &d), Ordering::Greater);
    }

    #[test]
    fn infeasible_and_unverified_points_never_make_the_frontier() {
        let cheap_but_broken = Metrics { fmax_mhz: 0, ..m(1, 1, 0, 0, 0) };
        let unverified = Metrics { verified: false, ..m(2, 2, 200, 1000, 10) };
        let honest = m(500, 500, 100, 800, 10);
        let evaluated = vec![(pt(), cheap_but_broken), (pt(), unverified), (pt(), honest)];
        let f = pareto_frontier(&evaluated);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].index, 2);
    }

    #[test]
    fn incomparable_points_all_survive_in_lut_order() {
        let evaluated = vec![
            (pt(), m(300, 100, 100, 100, 10)),
            (pt(), m(100, 300, 100, 100, 10)),
            (pt(), m(200, 200, 100, 100, 10)),
        ];
        let f = pareto_frontier(&evaluated);
        assert_eq!(f.len(), 3);
        let luts: Vec<u64> = f.iter().map(|e| e.metrics.resources.lut).collect();
        assert_eq!(luts, vec![100, 200, 300], "frontier must come out sorted by LUT");
    }
}
