//! Design-space exploration: search the hybrid interconnect family (and
//! its baseline/Medusa endpoints) for Pareto-efficient design points.
//!
//! The paper's evaluation compares exactly two designs at a handful of
//! geometries; its own complexity analysis (§II-B, §III-D) describes a
//! whole family in between. This subsystem turns the repo's pieces —
//! the fast simulation core, the calibrated `fpga` resource/timing
//! models, the `workload` zoo, and `util::parallel` sweeps — into a
//! search over that family:
//!
//! * [`space`] — the design-point grid (ports 4–64, interface width,
//!   transpose radix, rotator pipelining, CDC channel depths) and the
//!   evaluation of one point: analytical LUT/FF/BRAM, searched post-P&R
//!   peak frequency, and *achieved* bandwidth measured by actually
//!   running a `workload::zoo` probe network through the simulated
//!   fabric at that frequency.
//! * [`search`] — exhaustive grid, deterministic seeded random
//!   sampling, and seeded hill-climbing (all strategies are
//!   bit-identical under `MEDUSA_THREADS=1` vs parallel execution).
//! * [`pareto`] — the non-dominated frontier over
//!   {LUT, FF, Fmax, achieved bandwidth}.
//! * [`cache`] — an on-disk result cache keyed by a stable design-point
//!   hash, so repeated sweeps are incremental (warm runs re-read rather
//!   than re-simulate, and must produce bit-identical output).
//!
//! The CLI front-end is `medusa explore` (see `eval::explore` for the
//! table/CSV/JSON rendering).

pub mod cache;
pub mod pareto;
pub mod search;
pub mod space;

pub use cache::{point_key, ExploreCache};
pub use pareto::{pareto_frontier, FrontierEntry};
pub use search::{run_search, SearchResult, Strategy};
// Deprecated `_with` shim, kept importable for external callers; new
// code goes through `crate::run::RunOptions`.
#[allow(deprecated)]
pub use search::run_search_with;
pub use space::{DesignSpace, ExplorePoint, Metrics};
