//! System wiring: clocks, networks, arbiter, controller, layer processor.

use crate::accel::layer_processor::{LayerProcessor, Phase, PortGroup};
use crate::accel::prefetch::PortSchedule;
use crate::config::SystemConfig;
use crate::dram::{DdrTiming, MemoryController};
use crate::fault::{FaultSpec, FaultState};
use crate::fpga::timing::peak_frequency;
use crate::fpga::DesignPoint;
use crate::interconnect::arbiter::{Arbiter, MemCommand, Policy};
use crate::interconnect::medusa::MedusaTuning;
use crate::interconnect::{AnyReadNetwork, AnyWriteNetwork, Design, ReadNetwork, WriteNetwork};
use crate::obs::{CapSource, LeapBlock, SysProfile, SysRecorder};
use crate::sim::stats::Counter;
use crate::sim::{Channel, ClockDomain, Fired, Scheduler, Stats};
use crate::types::{Line, LineAddr, TaggedLine, Word};
use anyhow::Result;

/// Fabric domain index in the scheduler.
const DOM_FABRIC: usize = 0;
/// Memory-controller domain index.
const DOM_MEM: usize = 1;
/// Trunk-bus domain index (hierarchical designs only; systems without a
/// trunk register two domains and `Leap::fired[DOM_TRUNK]` stays 0, so
/// the bulk-apply below is unconditionally safe).
const DOM_TRUNK: usize = 2;

pub struct System {
    pub cfg: SystemConfig,
    pub fabric_mhz: f64,
    /// Statically dispatched networks: the per-cycle `tick`/`port_*`
    /// calls inline instead of going through a vtable.
    rd_net: AnyReadNetwork,
    wr_net: AnyWriteNetwork,
    pub arbiter: Arbiter,
    controller: MemoryController,
    /// The layer processors sharing this fabric — one per port group.
    /// Single-tenant systems have exactly one, covering every port; the
    /// workload scenario engine builds one per tenant.
    pub lps: Vec<LayerProcessor>,
    sched: Scheduler,
    /// Fabric -> mem commands.
    cmd_ch: Channel<MemCommand>,
    /// Mem -> fabric read data.
    rd_line_ch: Channel<TaggedLine>,
    /// Fabric -> mem write data.
    wr_data_ch: Channel<Line>,
    pub stats: Stats,
    fabric_cycles: u64,
    mem_cycles: u64,
    /// Trunk-clock edges elapsed (always 0 on designs without a trunk).
    trunk_cycles: u64,
    /// The materialized fault schedule (disabled by default; see
    /// [`System::install_faults`]).
    faults: FaultState,
    /// Tenants quiesced by the degrade policy: their layer processors
    /// are no longer ticked and their read ports are force-drained.
    quiesced: Vec<bool>,
    any_quiesced: bool,
    /// Words force-drained per quiesced tenant (the engine's recovery
    /// progress signal).
    quiesce_drained: Vec<u64>,
    /// Observability recorder (PR 9) — `None` unless profiling was
    /// enabled, in which case every hook *reads* existing state and
    /// writes only into this box. Nothing in here ever feeds back into
    /// simulation decisions: that is the zero-perturbation contract
    /// `tests/profile_conformance.rs` enforces.
    obs: Option<Box<SysRecorder>>,
}

/// Builder for [`System`]: port-group slicing and fault campaigns stop
/// threading through positional constructors.
///
/// ```ignore
/// let sys = System::builder(cfg).port_groups(&groups).faults(&spec).build()?;
/// ```
pub struct SystemBuilder {
    cfg: SystemConfig,
    groups: Option<Vec<PortGroup>>,
    faults: FaultSpec,
}

impl SystemBuilder {
    /// Slice the fabric ports into `groups`, one layer processor per
    /// group (multi-tenant scenarios). Default: one group covering the
    /// full fabric. Groups must be in-bounds; the scenario layer checks
    /// disjointness.
    pub fn port_groups(mut self, groups: &[PortGroup]) -> Self {
        self.groups = Some(groups.to_vec());
        self
    }

    /// Arm a fault campaign at build (see [`System::install_faults`]).
    /// The no-fault spec (the default) is a no-op.
    pub fn faults(mut self, spec: &FaultSpec) -> Self {
        self.faults = spec.clone();
        self
    }

    pub fn build(self) -> Result<System> {
        let groups = match &self.groups {
            Some(g) => g.clone(),
            None => vec![PortGroup::full(&self.cfg.geometry)],
        };
        let mut sys = System::construct(self.cfg, &groups)?;
        if !self.faults.is_none() {
            sys.install_faults(&self.faults)?;
        }
        Ok(sys)
    }
}

impl System {
    /// Build a system from a config. If no fabric clock is pinned, ask
    /// the P&R timing model what this design point closes at — the
    /// system-level consequence of Fig 6.
    pub fn new(cfg: SystemConfig) -> Result<Self> {
        System::builder(cfg).build()
    }

    /// Start building a system: groups, faults, then
    /// [`SystemBuilder::build`].
    pub fn builder(cfg: SystemConfig) -> SystemBuilder {
        SystemBuilder { cfg, groups: None, faults: FaultSpec::none() }
    }

    /// Build a system whose fabric ports are sliced into `groups`.
    /// Superseded by [`System::builder`].
    #[deprecated(
        since = "0.7.0",
        note = "use System::builder(cfg).port_groups(groups).build()"
    )]
    pub fn new_with_groups(cfg: SystemConfig, groups: &[PortGroup]) -> Result<Self> {
        System::builder(cfg).port_groups(groups).build()
    }

    /// The one true constructor behind [`SystemBuilder::build`].
    fn construct(cfg: SystemConfig, groups: &[PortGroup]) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(!groups.is_empty(), "system needs at least one port group");
        for g in groups {
            g.validate(&cfg.geometry)?;
        }
        let geom = cfg.geometry;
        let fabric_mhz = match cfg.fabric_clock_mhz {
            Some(f) => f,
            None => {
                let dp = DesignPoint { design: cfg.design, geometry: geom, dpus: cfg.dotprod_units };
                let f = peak_frequency(&dp);
                anyhow::ensure!(
                    f > 0,
                    "design point fails timing at 25 MHz ({:?}, {} DSPs) — see Fig 6",
                    cfg.design,
                    dp.dsps()
                );
                f as f64
            }
        };
        let (mut rd_net, mut wr_net) = if cfg.design == Design::Medusa && cfg.rotator_stages > 0 {
            let tuning = MedusaTuning { rotator_stages: cfg.rotator_stages };
            (
                AnyReadNetwork::medusa_with_tuning(geom, tuning),
                AnyWriteNetwork::medusa_with_tuning(geom, tuning),
            )
        } else {
            (AnyReadNetwork::build(cfg.design, geom), AnyWriteNetwork::build(cfg.design, geom))
        };
        // Propagate the backend's payload mode to every component that
        // touches line contents, before any traffic exists.
        rd_net.set_payload_mode(cfg.sim.payload);
        wr_net.set_payload_mode(cfg.sim.payload);
        let depths = cfg.channel_depths;
        let timing = if cfg.ddr3_timing { DdrTiming::ddr3_800() } else { DdrTiming::ideal() };
        let mut controller = MemoryController::new(timing, geom.words_per_line());
        controller.set_payload_mode(cfg.sim.payload);
        Ok(System {
            fabric_mhz,
            rd_net,
            wr_net,
            arbiter: Arbiter::new(geom.read_ports, geom.write_ports, Policy::RoundRobin),
            controller,
            lps: groups
                .iter()
                .map(|&g| {
                    let mut lp = LayerProcessor::new_grouped(geom, cfg.dotprod_units, g);
                    lp.set_payload_mode(cfg.sim.payload);
                    lp
                })
                .collect(),
            sched: {
                let mut domains = vec![
                    ClockDomain::from_mhz("fabric", fabric_mhz),
                    ClockDomain::from_mhz("mem", cfg.mem_clock_mhz),
                ];
                // Hierarchical designs carry the trunk clock in the
                // design spec itself (so trace headers replay it with
                // zero extra plumbing); it becomes a third scheduler
                // domain.
                if let Design::Hierarchical(hc) = cfg.design {
                    domains.push(ClockDomain::from_mhz("trunk", hc.trunk_mhz as f64));
                }
                Scheduler::new(domains)
            },
            cmd_ch: Channel::new("cmd", depths.cmd),
            rd_line_ch: Channel::new("rd_lines", depths.rd_line),
            wr_data_ch: Channel::new("wr_lines", depths.wr_data),
            stats: Stats::new(),
            fabric_cycles: 0,
            mem_cycles: 0,
            trunk_cycles: 0,
            faults: FaultState::none(),
            quiesced: vec![false; groups.len()],
            any_quiesced: false,
            quiesce_drained: vec![0; groups.len()],
            obs: None,
            cfg,
        })
    }

    /// Materialize and arm a fault campaign. Call before any traffic;
    /// per-tenant fault streams are keyed by each group's read base, so
    /// a given port group sees the same schedule regardless of tenant
    /// ordering. A no-fault spec leaves the system bit-identical to one
    /// that never heard of faults.
    pub fn install_faults(&mut self, spec: &FaultSpec) -> Result<()> {
        let bases: Vec<usize> = self.lps.iter().map(|lp| lp.group().read_base).collect();
        self.faults = FaultState::build(spec, &bases)?;
        Ok(())
    }

    /// The installed campaign's spec (the no-fault spec by default).
    pub fn fault_spec(&self) -> &FaultSpec {
        &self.faults.spec
    }

    /// Degrade policy: stop ticking tenant `t`'s layer processor and
    /// start force-draining its read ports so shared buffers (and the
    /// CDC crossing behind them) cannot wedge the other tenants.
    pub fn quiesce_tenant(&mut self, t: usize) {
        self.quiesced[t] = true;
        self.any_quiesced = true;
    }

    pub fn is_quiesced(&self, t: usize) -> bool {
        self.quiesced.get(t).copied().unwrap_or(false)
    }

    /// Words force-drained from tenant `t`'s read ports since it was
    /// quiesced.
    pub fn quiesce_drained(&self, t: usize) -> u64 {
        self.quiesce_drained.get(t).copied().unwrap_or(0)
    }

    /// Turn on the observability recorder (PR 9) with the given
    /// utilization window, in fabric cycles. Call before any traffic so
    /// the edge-attribution invariant (`stepped + leapt == elapsed`)
    /// holds from cycle 0. Profiling never perturbs the run: enabled
    /// and disabled runs are bit-identical on every observable.
    pub fn enable_profiling(&mut self, window: u64) {
        let domains: Vec<&'static str> =
            (0..self.sched.num_domains()).map(|i| self.sched.domain(i).name).collect();
        self.obs = Some(Box::new(SysRecorder::new(domains, self.lps.len(), window)));
    }

    pub fn profiling_enabled(&self) -> bool {
        self.obs.is_some()
    }

    /// Detach and finalize the recorder (None if profiling was off).
    pub fn take_profile(&mut self) -> Option<SysProfile> {
        self.obs.take().map(|r| r.finish())
    }

    /// Declare the external cap source in force for subsequent
    /// [`System::try_leap_idle`] calls (the drive loop's tenant-start /
    /// serving-horizon caps). No-op unless profiling is on; pure
    /// attribution metadata — never read by the leap itself.
    pub fn obs_note_cap_source(&mut self, src: CapSource) {
        if let Some(obs) = self.obs.as_deref_mut() {
            obs.pending_cap = src;
        }
    }

    /// Record the serving queue depth at the current fabric cycle
    /// (change-driven; no-op unless profiling is on).
    pub fn obs_serving_depth(&mut self, depth: u64) {
        let cycle = self.fabric_cycles;
        if let Some(obs) = self.obs.as_deref_mut() {
            obs.serving_depth_sample(cycle, depth);
        }
    }

    /// Record the cumulative shed-request count at the current fabric
    /// cycle (change-driven; no-op unless profiling is on).
    pub fn obs_serving_shed(&mut self, shed: u64) {
        let cycle = self.fabric_cycles;
        if let Some(obs) = self.obs.as_deref_mut() {
            obs.serving_shed_sample(cycle, shed);
        }
    }

    /// Count a refused leap attempt against `why` (no-op when
    /// profiling is off).
    #[inline]
    fn obs_refuse(&mut self, why: LeapBlock) {
        if let Some(obs) = self.obs.as_deref_mut() {
            obs.leap.refusals[why as usize] += 1;
        }
    }

    /// Attribute a leap refusal to the first blocking component,
    /// mirroring [`System::leap_horizon`]'s check order exactly. Only
    /// meaningful right after `leap_horizon` returned `None`; reads the
    /// same state and nothing else.
    fn leap_block(&self) -> LeapBlock {
        if self.cmd_ch.occupancy() != 0
            || self.rd_line_ch.occupancy() != 0
            || self.wr_data_ch.occupancy() != 0
        {
            return LeapBlock::ChannelOccupied;
        }
        // Trunk traffic is a subset of "network busy"; probe it first so
        // hierarchical trunk queues attribute distinctly.
        if self.rd_net.trunk_occupancy() + self.wr_net.trunk_occupancy() > 0 {
            return LeapBlock::TrunkQueue;
        }
        if !self.rd_net.is_leap_idle() || !self.wr_net.is_leap_idle() {
            return LeapBlock::NetworkBusy;
        }
        if !self.arbiter.is_leap_idle() {
            return LeapBlock::ArbiterBusy;
        }
        if !self.controller.is_idle() {
            return LeapBlock::ControllerBusy;
        }
        LeapBlock::LpLoadDrain
    }

    /// Per-stepped-edge recording: domain edge counts plus, on fabric
    /// edges, one utilization sample. Called after the edge handlers so
    /// occupancies reflect the post-edge state. Field-disjoint borrows
    /// only — the recorder is written, everything else is read.
    fn record_step(&mut self, fired: Fired) {
        let obs = match self.obs.as_deref_mut() {
            Some(o) => o,
            None => return,
        };
        for (d, stepped) in obs.stepped.iter_mut().enumerate() {
            if fired.contains(d) {
                *stepped += 1;
            }
        }
        if fired.contains(DOM_FABRIC) {
            obs.util.begin_edge(self.fabric_cycles - 1);
            for (g, lp) in self.lps.iter().enumerate() {
                if lp.phase() != Phase::Done {
                    obs.util.mark_busy(g);
                }
            }
            obs.util.add_occupancy(
                self.cmd_ch.occupancy() as u64,
                self.rd_line_ch.occupancy() as u64,
                self.wr_data_ch.occupancy() as u64,
                (self.rd_net.trunk_occupancy() + self.wr_net.trunk_occupancy()) as u64,
            );
        }
    }

    /// One-glance state dump: per-domain elapsed cycles plus each layer
    /// processor's phase and progress counters. Shared by the watchdog's
    /// `SimError::TenantStalled` report, the engine's edge-budget error,
    /// and the `run_until_*` timeout diagnostics.
    pub fn state_dump(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "  clocks: fabric={} cycles, mem={} cycles{}, t={} ps",
            self.fabric_cycles,
            self.mem_cycles,
            if matches!(self.cfg.design, Design::Hierarchical(_)) {
                format!(", trunk={} cycles", self.trunk_cycles)
            } else {
                String::new()
            },
            self.now_ps()
        );
        let _ = writeln!(
            s,
            "  channels: cmd={} rd_line={} wr_data={}; arbiter: pending={} writes_in_flight={}; controller: {}",
            self.cmd_ch.occupancy(),
            self.rd_line_ch.occupancy(),
            self.wr_data_ch.occupancy(),
            self.arbiter.pending_requests(),
            self.arbiter.writes_in_flight(),
            if self.controller.is_idle() { "idle" } else { "busy" },
        );
        for (i, lp) in self.lps.iter().enumerate() {
            let _ = writeln!(
                s,
                "  lp{i}: phase={:?} compute_left={} load={} compute={} drain={}{}",
                lp.phase(),
                lp.compute_cycles_left(),
                lp.load_cycles,
                lp.compute_cycles,
                lp.drain_cycles,
                if self.is_quiesced(i) {
                    format!(" [quiesced, {} words drained]", self.quiesce_drained(i))
                } else {
                    String::new()
                },
            );
        }
        s
    }

    /// [`System::state_dump`] plus the serving front-end's queue and
    /// batcher state when a serving run is active. The system does not
    /// own the `ServingRun` (the scenario engine drives it), so the
    /// serving-aware dump takes it as an argument; watchdog and
    /// edge-budget diagnostics on serving runs route through here.
    pub fn state_dump_with(&self, serving: Option<&crate::serving::ServingRun>) -> String {
        let mut s = self.state_dump();
        if let Some(srv) = serving {
            s.push_str(&srv.state_dump());
        }
        s
    }

    pub fn controller_mut(&mut self) -> &mut MemoryController {
        &mut self.controller
    }

    pub fn controller(&self) -> &MemoryController {
        &self.controller
    }

    /// The (single) layer processor of a full-fabric system. Multi-group
    /// systems index `lps` directly.
    pub fn lp(&self) -> &LayerProcessor {
        &self.lps[0]
    }

    pub fn lp_mut(&mut self) -> &mut LayerProcessor {
        &mut self.lps[0]
    }

    pub fn fabric_cycles(&self) -> u64 {
        self.fabric_cycles
    }

    pub fn mem_cycles(&self) -> u64 {
        self.mem_cycles
    }

    /// Trunk-clock edges elapsed (0 on designs without a trunk domain).
    pub fn trunk_cycles(&self) -> u64 {
        self.trunk_cycles
    }

    pub fn now_ps(&self) -> u64 {
        self.sched.now_ps()
    }

    /// Advance to the next clock edge(s) and execute them.
    ///
    /// Allocation-free: the scheduler returns a `Copy` bitmask and both
    /// edge handlers dispatch statically.
    #[inline]
    pub fn step(&mut self) {
        let fired = self.sched.step();
        if fired.contains(DOM_FABRIC) {
            self.fabric_edge();
        }
        if fired.contains(DOM_MEM) {
            self.mem_edge();
        }
        if fired.contains(DOM_TRUNK) {
            self.trunk_edge();
        }
        // Observability is read-only and off the hot path: one branch
        // when disabled, pure recording when enabled.
        if self.obs.is_some() {
            self.record_step(fired);
        }
    }

    /// Batched fast path: advance `n` scheduler edges with the dispatch
    /// hoisted out of any caller-side bookkeeping. Use this when no
    /// per-edge termination check is needed (benchmarks, fixed-length
    /// warm-up, fast-forward). `step` is `#[inline]`, so this compiles
    /// to the same loop as hand-inlining it while keeping one copy of
    /// the edge-dispatch logic.
    ///
    /// Under the leap backend ([`EdgeMode::Leap`]) globally idle spans
    /// are covered by [`System::try_leap_idle`] instead of ticked; the
    /// post-state after `n` edges is bit-identical either way.
    ///
    /// [`EdgeMode::Leap`]: crate::config::EdgeMode::Leap
    pub fn run_edges(&mut self, n: u64) {
        let mut remaining = n;
        while remaining > 0 {
            // A leap of k fabric edges always covers >= k scheduler
            // steps, so capping the fabric span at `remaining` (plus
            // the explicit step budget) can never overshoot.
            if let Some(leap) = self.try_leap_idle(remaining, remaining) {
                remaining -= leap.steps;
                continue;
            }
            self.step();
            remaining -= 1;
        }
    }

    /// The idle-span horizon: `None` when some component can act on the
    /// very next edge; otherwise the number of fabric cycles for which
    /// every clocked component is provably inert (`u64::MAX` = forever,
    /// absent external events). Each component answers its own
    /// `next_activity_edge()` question: CDC channels by occupancy, the
    /// networks and arbiter by [`is_leap_idle`], the memory controller
    /// by command-engine idleness, the layer processors by their
    /// compute countdown.
    ///
    /// [`is_leap_idle`]: crate::interconnect::ReadNetwork::is_leap_idle
    fn leap_horizon(&self) -> Option<u64> {
        if self.cmd_ch.occupancy() != 0
            || self.rd_line_ch.occupancy() != 0
            || self.wr_data_ch.occupancy() != 0
            || !self.rd_net.is_leap_idle()
            || !self.wr_net.is_leap_idle()
            || !self.arbiter.is_leap_idle()
            || !self.controller.is_idle()
        {
            return None;
        }
        let mut horizon = u64::MAX;
        for lp in &self.lps {
            match lp.phase() {
                Phase::Load | Phase::Drain => return None,
                Phase::Compute => {
                    let left = lp.compute_cycles_left();
                    // left == 0: the flip already happened and the
                    // coordinator hasn't reacted — further ticks only
                    // accumulate compute_cycles (bulk-appliable).
                    if left > 0 {
                        horizon = horizon.min(left);
                    }
                }
                Phase::Done => {}
            }
        }
        Some(horizon)
    }

    /// Attempt one idle-span leap (no-op returning `None` under the
    /// stepwise backend, when any component is active, or when the
    /// caps allow no progress). On success the system state — cycles,
    /// stats, time, component state — is bit-identical to executing
    /// the returned number of [`System::step`]s.
    ///
    /// `max_fabric` bounds the fabric cycles covered (run-loop budgets
    /// and the scenario engine's tenant start cycles need exact stop
    /// points); `max_steps` bounds the scheduler steps replaced
    /// ([`System::run_edges`]' contract).
    pub fn try_leap_idle(&mut self, max_fabric: u64, max_steps: u64) -> Option<crate::sim::Leap> {
        // Stepwise backends never attempt (attempts stays 0 and the
        // attribution invariants hold trivially); every path below the
        // bump records exactly one refusal or one taken leap, so
        // `attempts == taken + refusals.sum()` by construction. The
        // recording is observation-only: identical control flow, same
        // probes a non-profiled run evaluates, in the same order.
        if !self.cfg.sim.edges.is_leap() {
            return None;
        }
        if let Some(obs) = self.obs.as_deref_mut() {
            obs.leap.attempts += 1;
        }
        // Fault edges cap the horizon exactly like tenant start cycles:
        // a leap may reach the next slowdown-window start or wedge cycle
        // but never cross it, and leaping is disabled outright while a
        // suppression (slowdown/wedge/quiesce) is in force — those
        // per-cycle effects must be stepped to stay bit-identical.
        if self.any_quiesced {
            self.obs_refuse(LeapBlock::Quiesced);
            return None;
        }
        let Some(fault_cap) = self.faults.fabric_leap_cap(self.fabric_cycles) else {
            self.obs_refuse(LeapBlock::FaultWindow);
            return None;
        };
        let Some(horizon) = self.leap_horizon() else {
            if self.obs.is_some() {
                let why = self.leap_block();
                self.obs_refuse(why);
            }
            return None;
        };
        let k = horizon.min(max_fabric).min(fault_cap);
        if k == 0 {
            self.obs_refuse(LeapBlock::ZeroCap);
            return None;
        }
        let Some(leap) = self.sched.leap(DOM_FABRIC, k, max_steps) else {
            self.obs_refuse(LeapBlock::StepBudget);
            return None;
        };
        let fab = leap.fired[DOM_FABRIC];
        let mem = leap.fired[DOM_MEM];
        if let Some(obs) = self.obs.as_deref_mut() {
            obs.leap.taken += 1;
            for (d, leapt) in obs.leapt.iter_mut().enumerate() {
                *leapt += leap.fired[d];
            }
            // What bounded this leap? Step-budget truncation first
            // (the scheduler covered fewer fabric edges than asked);
            // otherwise whichever term of min(horizon, max_fabric,
            // fault_cap) won, ties to the intrinsic horizon.
            let src = if fab < k {
                CapSource::StepBudget
            } else if horizon <= max_fabric && horizon <= fault_cap {
                if horizon == u64::MAX {
                    CapSource::Uncapped
                } else {
                    CapSource::LpCompute
                }
            } else if fault_cap <= max_fabric {
                CapSource::FaultWindow
            } else {
                // The caller's cap won: the drive loop names it via
                // obs_note_cap_source (tenant start / serving horizon);
                // plain run loops default to the edge budget.
                obs.pending_cap
            };
            obs.leap.caps[src as usize] += 1;
        }
        // Trunk edges over an idle span are pure no-ops (the networks'
        // is_leap_idle gate requires the trunk queues empty), so the
        // counter bump is the entire bulk-apply. `fired[DOM_TRUNK]` is
        // 0 on two-domain systems.
        self.trunk_cycles += leap.fired[DOM_TRUNK];
        // Bulk-apply exactly what the skipped edges would have done:
        // fabric edges advance compute countdowns, memory edges bump
        // the controller's idle counter — except the memory edges that
        // fall inside a scheduled refresh window, which a stepwise run
        // would count as refresh stalls instead (closed-form split, so
        // the leap stays exact under DRAM fault campaigns).
        self.fabric_cycles += fab;
        for lp in &mut self.lps {
            if lp.phase() == Phase::Compute {
                lp.skip_compute_cycles(fab);
            }
        }
        self.mem_cycles += mem;
        if mem > 0 {
            let refresh = self.faults.refresh_count_in(self.mem_cycles - mem, self.mem_cycles);
            if refresh > 0 {
                self.controller.skip_refresh_cycles(refresh, &mut self.stats);
            }
            self.controller.skip_idle_cycles(mem - refresh, &mut self.stats);
        }
        Some(leap)
    }

    fn fabric_edge(&mut self) {
        let c = self.fabric_cycles;
        self.fabric_cycles += 1;
        // 1. Datapath tick.
        self.rd_net.tick(c, &mut self.stats);
        self.wr_net.tick(c, &mut self.stats);
        // 2. Memory-side adapter: one read line per fabric cycle into the
        //    read network (this is the 512-bit interface crossing into
        //    the fabric domain — if the fabric is slower than the
        //    controller, bandwidth is lost right here, which is exactly
        //    the Fig 6 system-level effect).
        if let Some(tl) = self.rd_line_ch.peek() {
            if self.faults.cdc_active(c) {
                // Scheduled CDC stall: the crossing delivers nothing
                // this cycle. Counted only when a line was actually
                // ready — a stall over an empty crossing is a no-op,
                // which is what lets idle-edge leaps ignore CDC windows
                // (a leap requires the crossing to be empty).
                self.stats.bump(Counter::FaultCdcStallCycles);
            } else if self.rd_net.mem_can_deliver(tl.port) {
                let tl = self.rd_line_ch.pop().unwrap();
                let port = tl.port;
                self.rd_net.mem_deliver(tl);
                self.arbiter.on_read_line_delivered(port);
                self.stats.bump(Counter::SysReadLinesIntoFabric);
                // Corrupt fault: every line delivery advances the
                // schedule; scheduled events tag this line corrupt and
                // a seeded parity bit decides whether the fabric's line
                // parity catches it. Detection-only — the payload is
                // never mutated — so golden checks and payload elision
                // stay bit-identical.
                if let Some(cs) = self.faults.corrupt.as_mut() {
                    let idx = cs.delivered;
                    cs.delivered += 1;
                    if let Some(detected) = cs.event(idx) {
                        self.stats.bump(Counter::FaultCorruptInjected);
                        self.stats.bump(if detected {
                            Counter::FaultDetected
                        } else {
                            Counter::FaultMasked
                        });
                    }
                }
            } else {
                self.stats.bump(Counter::SysReadLineBackpressure);
            }
        }
        // 3. Arbiter: issue commands, stream write data.
        self.arbiter.tick(
            &self.rd_net,
            &mut self.wr_net,
            &mut self.cmd_ch,
            &mut self.wr_data_ch,
            &mut self.stats,
        );
        // 4. Each layer processor moves its port group's words — unless
        //    its tenant's tick is suppressed this cycle by a scheduled
        //    slowdown window, a permanent wedge, or a degrade-policy
        //    quiesce (a suppressed processor is the fault model for a
        //    stalled port group: it takes no words, submits no bursts,
        //    and its progress counters freeze).
        let inject = !self.faults.is_none() || self.any_quiesced;
        for (t, lp) in self.lps.iter_mut().enumerate() {
            if inject {
                let slow = self.faults.lp_slow_active(t, c);
                if slow || self.quiesced[t] || self.faults.wedged(t, c) {
                    if slow && lp.phase() != Phase::Done {
                        self.stats.bump(Counter::FaultLpSlowdownCycles);
                    }
                    continue;
                }
            }
            lp.tick(&mut self.rd_net, &mut self.wr_net, &mut self.arbiter, &mut self.stats);
        }
        // 4b. Force-drain quiesced tenants' read ports (one word per
        //     port per cycle, like a live processor would) so shared
        //     buffers and the CDC crossing behind them cannot wedge the
        //     surviving tenants.
        if self.any_quiesced {
            for t in 0..self.lps.len() {
                if !self.quiesced[t] {
                    continue;
                }
                let g = self.lps[t].group();
                for p in g.read_base..g.read_base + g.read_ports {
                    if self.rd_net.port_word_available(p) && self.rd_net.port_take_word(p).is_some()
                    {
                        self.quiesce_drained[t] += 1;
                    }
                }
            }
        }
        // 5. Commit fabric-side channel pushes.
        self.cmd_ch.commit();
        self.wr_data_ch.commit();
    }

    /// One trunk-clock edge: both networks advance their trunk
    /// pipelines. Only reachable on hierarchical designs (the trunk
    /// domain exists only when the design registered one); flat
    /// networks' default `trunk_tick` is a no-op regardless.
    fn trunk_edge(&mut self) {
        self.trunk_cycles += 1;
        self.rd_net.trunk_tick(&mut self.stats);
        self.wr_net.trunk_tick(&mut self.stats);
    }

    fn mem_edge(&mut self) {
        let c = self.mem_cycles;
        self.mem_cycles += 1;
        // A scheduled DRAM refresh window freezes the controller for
        // the cycle (no command accept, no line return, no write
        // drain); wall-clock time still passes through the window.
        if self.faults.refresh_active(c) {
            self.controller.refresh_stall(c, &mut self.stats);
        } else {
            self.controller.tick(c, &mut self.cmd_ch, &mut self.rd_line_ch, &mut self.wr_data_ch, &mut self.stats);
        }
        self.rd_line_ch.commit();
    }

    /// Run until every layer processor's load completes and its compute
    /// stall elapses. Returns fabric cycles spent.
    pub fn run_until_compute_done(&mut self, max_fabric_cycles: u64) -> Result<u64> {
        let start = self.fabric_cycles;
        while !self.lps.iter().all(|lp| lp.compute_done()) {
            // Leap backend: skip idle spans, capped at the remaining
            // budget so the timeout error fires at the same elapsed
            // cycle a stepwise run would reach it.
            let budget = max_fabric_cycles.saturating_sub(self.fabric_cycles - start);
            if self.try_leap_idle(budget, u64::MAX).is_none() {
                self.step();
            }
            anyhow::ensure!(
                self.fabric_cycles - start < max_fabric_cycles,
                "load/compute did not finish within {max_fabric_cycles} fabric cycles\n{}  stats:\n{}",
                self.state_dump(),
                self.stats
            );
        }
        Ok(self.fabric_cycles - start)
    }

    /// No command, write data, or write burst is still anywhere between
    /// the arbiter and the DRAM store.
    pub fn writes_flushed(&self) -> bool {
        self.arbiter.pending_requests() == 0
            && self.arbiter.writes_in_flight() == 0
            && self.wr_data_ch.is_empty()
            && self.cmd_ch.is_empty()
            && self.controller.is_idle()
    }

    /// Run until every drain phase completes AND every issued write has
    /// landed in DRAM.
    pub fn run_until_drained(&mut self, max_fabric_cycles: u64) -> Result<u64> {
        let start = self.fabric_cycles;
        loop {
            let lp_done = self.lps.iter().all(|lp| lp.phase() == Phase::Done);
            if lp_done && self.writes_flushed() {
                return Ok(self.fabric_cycles - start);
            }
            let budget = max_fabric_cycles.saturating_sub(self.fabric_cycles - start);
            if self.try_leap_idle(budget, u64::MAX).is_none() {
                self.step();
            }
            anyhow::ensure!(
                self.fabric_cycles - start < max_fabric_cycles,
                "drain did not finish within {max_fabric_cycles} fabric cycles\n{}  stats:\n{}",
                self.state_dump(),
                self.stats
            );
        }
    }

    /// Reassemble the words a set of port schedules loaded, keyed by
    /// line address.
    pub fn reassemble(
        &self,
        scheds: &[PortSchedule],
        loaded: impl Fn(usize) -> Vec<Word>,
    ) -> std::collections::HashMap<LineAddr, Vec<Word>> {
        let n = self.cfg.geometry.words_per_line();
        let mut out = std::collections::HashMap::new();
        for (p, sched) in scheds.iter().enumerate() {
            let words = loaded(p);
            let mut idx = 0usize;
            for run in &sched.runs {
                for a in run.base..run.end() {
                    out.insert(a, words[idx..idx + n].to_vec());
                    idx += n;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::prefetch::{partition, Region};

    fn small_cfg(design: Design) -> SystemConfig {
        SystemConfig {
            design,
            geometry: crate::types::Geometry {
                w_line: 64,
                w_acc: 16,
                read_ports: 4,
                write_ports: 4,
                max_burst: 4,
            },
            dotprod_units: 4,
            mem_clock_mhz: 200.0,
            fabric_clock_mhz: Some(200.0),
            ddr3_timing: false,
            rotator_stages: 0,
            channel_depths: Default::default(),
            seed: 1,
            sim: Default::default(),
        }
    }

    #[test]
    fn load_roundtrip_both_designs() {
        for design in [Design::Baseline, Design::Medusa] {
            let mut sys = System::new(small_cfg(design)).unwrap();
            let n = sys.cfg.geometry.words_per_line();
            // Preload 16 lines of known data.
            sys.controller_mut().preload(
                0,
                (0..16u64).map(|i| Line::from_words((0..n as u64).map(|y| i * 100 + y).collect())),
            );
            let scheds = partition(&[Region { base: 0, lines: 16 }], 4);
            sys.lp_mut().begin_layer(&scheds, 1);
            sys.run_until_compute_done(100_000).unwrap();
            let lines = sys.reassemble(&scheds, |p| sys.lp().loaded(p).to_vec());
            for i in 0..16u64 {
                let expect: Vec<Word> = (0..n as u64).map(|y| i * 100 + y).collect();
                assert_eq!(lines[&i], expect, "{design:?} line {i}");
            }
        }
    }

    #[test]
    fn write_roundtrip_both_designs() {
        for design in [Design::Baseline, Design::Medusa] {
            let mut sys = System::new(small_cfg(design)).unwrap();
            let n = sys.cfg.geometry.words_per_line();
            // No reads; straight to compute, then drain 8 lines.
            let scheds = partition(&[], 4);
            sys.lp_mut().begin_layer(&scheds, 1);
            sys.run_until_compute_done(10_000).unwrap();
            let wscheds = partition(&[Region { base: 32, lines: 8 }], 4);
            let data: Vec<std::collections::VecDeque<Word>> = wscheds
                .iter()
                .map(|s| {
                    let mut q = std::collections::VecDeque::new();
                    for r in &s.runs {
                        for a in r.base..r.end() {
                            for y in 0..n as u64 {
                                q.push_back(a * 7 + y);
                            }
                        }
                    }
                    q
                })
                .collect();
            sys.lp_mut().supply_output(&wscheds, data);
            sys.run_until_drained(100_000).unwrap();
            for a in 32..40u64 {
                let line = sys.controller().dump(a, 1).remove(0);
                let expect: Vec<Word> = (0..n as u64).map(|y| (a * 7 + y) & 0xffff).collect();
                assert_eq!(line.words(), &expect[..], "{design:?} line {a}");
            }
        }
    }

    #[test]
    fn slower_fabric_loses_bandwidth() {
        // Same load at 200 vs 50 MHz fabric: the slow fabric must take
        // ~4x the wall-clock time (Fig 6's system-level consequence).
        let time_for = |mhz: f64| -> u64 {
            let mut cfg = small_cfg(Design::Medusa);
            cfg.fabric_clock_mhz = Some(mhz);
            let mut sys = System::new(cfg).unwrap();
            sys.controller_mut().preload(0, (0..512u64).map(|_| Line::zeroed(4)));
            let scheds = partition(&[Region { base: 0, lines: 512 }], 4);
            sys.lp_mut().begin_layer(&scheds, 1);
            sys.run_until_compute_done(10_000_000).unwrap();
            sys.now_ps()
        };
        let fast = time_for(200.0);
        let slow = time_for(50.0);
        let ratio = slow as f64 / fast as f64;
        // Ratio approaches 4x asymptotically; fixed command/latency
        // overheads (constant in ns) keep it below that on this length.
        assert!(ratio > 2.5, "50MHz fabric should be ~3-4x slower, got {ratio:.2}x");
    }

    #[test]
    fn run_edges_matches_stepwise_execution() {
        // The batched fast path must be cycle-for-cycle identical to
        // per-step driving (same channels, same stats, same time).
        let build = || {
            let mut sys = System::new(small_cfg(Design::Medusa)).unwrap();
            sys.controller_mut().preload(
                0,
                (0..64u64).map(|i| Line::from_words((0..4u64).map(|y| i * 10 + y).collect())),
            );
            let scheds = partition(&[Region { base: 0, lines: 64 }], 4);
            sys.lp_mut().begin_layer(&scheds, 1);
            sys
        };
        let mut a = build();
        let mut b = build();
        a.run_edges(500);
        for _ in 0..500 {
            b.step();
        }
        assert_eq!(a.now_ps(), b.now_ps());
        assert_eq!(a.fabric_cycles(), b.fabric_cycles());
        assert_eq!(a.mem_cycles(), b.mem_cycles());
        assert_eq!(
            a.stats.get("sys.read_lines_into_fabric"),
            b.stats.get("sys.read_lines_into_fabric")
        );
        assert_eq!(a.stats.get("lp.words_loaded"), b.stats.get("lp.words_loaded"));
    }

    /// Build a compute-heavy run (long modelled stall after a short
    /// load) under the given backend and drive it to compute-done;
    /// returns the system for state comparison.
    fn compute_heavy(sim: crate::config::SimBackend) -> System {
        let mut cfg = small_cfg(Design::Medusa);
        cfg.sim = sim;
        let mut sys = System::new(cfg).unwrap();
        let n = sys.cfg.geometry.words_per_line();
        if !sim.payload.is_elided() {
            sys.controller_mut().preload(
                0,
                (0..32u64).map(|i| Line::from_words((0..n as u64).map(|y| i * 10 + y).collect())),
            );
        }
        let scheds = partition(&[Region { base: 0, lines: 32 }], 4);
        // 4 DPUs x 32 lanes: 2^20 MACs -> 8192 stall cycles of pure idle.
        sys.lp_mut().begin_layer(&scheds, 1 << 20);
        sys.run_until_compute_done(1_000_000).unwrap();
        sys
    }

    fn assert_same_observables(a: &System, b: &System) {
        assert_eq!(a.fabric_cycles(), b.fabric_cycles());
        assert_eq!(a.mem_cycles(), b.mem_cycles());
        assert_eq!(a.now_ps(), b.now_ps());
        for &id in crate::sim::stats::Counter::ALL.iter() {
            assert_eq!(a.stats.count(id), b.stats.count(id), "counter {}", id.name());
        }
        for &id in crate::sim::stats::SampleId::ALL.iter() {
            let (sa, sb) = (a.stats.series_of(id), b.stats.series_of(id));
            assert_eq!((sa.sum, sa.count, sa.min, sa.max), (sb.sum, sb.count, sb.min, sb.max));
        }
    }

    #[test]
    fn leap_backend_is_bit_identical_to_stepwise() {
        use crate::config::{EdgeMode, SimBackend};
        let step = compute_heavy(SimBackend::full());
        let leap = compute_heavy(SimBackend {
            edges: EdgeMode::Leap,
            ..SimBackend::full()
        });
        assert_same_observables(&step, &leap);
        // The leap run really did skip the stall (teeth: the stall is
        // thousands of cycles; if leaping never engaged, this test
        // still passes but the perf claim is dead — so check state).
        assert!(leap.lp().compute_done());
    }

    #[test]
    fn elided_backend_is_stats_identical_to_full() {
        use crate::config::{PayloadMode, SimBackend};
        let full = compute_heavy(SimBackend::full());
        let elided = compute_heavy(SimBackend {
            payload: PayloadMode::Elided,
            ..SimBackend::full()
        });
        assert_same_observables(&full, &elided);
    }

    #[test]
    fn fast_backend_run_edges_matches_stepwise() {
        use crate::config::SimBackend;
        let build = |sim: crate::config::SimBackend| {
            let mut cfg = small_cfg(Design::Medusa);
            cfg.sim = sim;
            let mut sys = System::new(cfg).unwrap();
            let scheds = partition(&[Region { base: 0, lines: 8 }], 4);
            sys.lp_mut().begin_layer(&scheds, 1 << 18);
            sys
        };
        // Drive both for the same number of scheduler edges, spanning
        // load + a long idle compute stall; every observable matches.
        let mut a = build(SimBackend::fast());
        let mut b = build(SimBackend::full());
        a.run_edges(5000);
        for _ in 0..5000 {
            b.step();
        }
        assert_same_observables(&a, &b);
    }

    #[test]
    fn faulted_run_is_bit_identical_across_backends() {
        use crate::config::{EdgeMode, PayloadMode, SimBackend};
        use crate::fault::FaultSpec;
        let spec = FaultSpec::parse_cli("dram_refresh=64/8,cdc=96/6,slow=128/12,corrupt=7,seed=3")
            .unwrap();
        let run = |sim: SimBackend| {
            let mut cfg = small_cfg(Design::Medusa);
            cfg.sim = sim;
            let mut sys = System::new(cfg).unwrap();
            sys.install_faults(&spec).unwrap();
            let n = sys.cfg.geometry.words_per_line();
            if !sim.payload.is_elided() {
                sys.controller_mut().preload(
                    0,
                    (0..32u64)
                        .map(|i| Line::from_words((0..n as u64).map(|y| i * 10 + y).collect())),
                );
            }
            let scheds = partition(&[Region { base: 0, lines: 32 }], 4);
            sys.lp_mut().begin_layer(&scheds, 1 << 18);
            sys.run_until_compute_done(1_000_000).unwrap();
            sys
        };
        let step = run(SimBackend::full());
        let leap = run(SimBackend { edges: EdgeMode::Leap, ..SimBackend::full() });
        assert_same_observables(&step, &leap);
        let elided = run(SimBackend { payload: PayloadMode::Elided, ..SimBackend::full() });
        assert_same_observables(&step, &elided);
        let fast = run(SimBackend::fast());
        assert_same_observables(&step, &fast);
        // The campaign really fired (teeth for the whole comparison).
        assert!(step.stats.get("fault.dram_refresh_stall_cycles") > 0);
        assert!(step.stats.get("fault.lp_slowdown_cycles") > 0);
        assert!(step.stats.get("fault.corrupt_injected") > 0);
        // Detection-only corruption: payload untouched, data verifies.
        let loaded = step.lp().loaded(0);
        assert!(!loaded.is_empty());
    }

    #[test]
    fn custom_channel_depths_still_roundtrip() {
        // Shallow CDC channels throttle but must never corrupt data.
        let mut cfg = small_cfg(Design::Medusa);
        cfg.channel_depths = crate::config::ChannelDepths { cmd: 1, rd_line: 2, wr_data: 1 };
        let mut sys = System::new(cfg).unwrap();
        let n = sys.cfg.geometry.words_per_line();
        sys.controller_mut().preload(
            0,
            (0..16u64).map(|i| Line::from_words((0..n as u64).map(|y| i * 100 + y).collect())),
        );
        let scheds = partition(&[Region { base: 0, lines: 16 }], 4);
        sys.lp_mut().begin_layer(&scheds, 1);
        sys.run_until_compute_done(200_000).unwrap();
        let lines = sys.reassemble(&scheds, |p| sys.lp().loaded(p).to_vec());
        for i in 0..16u64 {
            let expect: Vec<Word> = (0..n as u64).map(|y| i * 100 + y).collect();
            assert_eq!(lines[&i], expect, "line {i}");
        }
    }

    #[test]
    fn zero_depth_channel_rejected() {
        let mut cfg = small_cfg(Design::Medusa);
        cfg.channel_depths.rd_line = 0;
        assert!(System::new(cfg).is_err());
    }

    #[test]
    fn two_port_groups_load_concurrently_without_crosstalk() {
        use crate::accel::layer_processor::PortGroup;
        let groups = [
            PortGroup { read_base: 0, read_ports: 2, write_base: 0, write_ports: 2 },
            PortGroup { read_base: 2, read_ports: 2, write_base: 2, write_ports: 2 },
        ];
        let mut sys =
            System::builder(small_cfg(Design::Medusa)).port_groups(&groups).build().unwrap();
        let n = sys.cfg.geometry.words_per_line();
        sys.controller_mut().preload(
            0,
            (0..32u64).map(|i| Line::from_words((0..n as u64).map(|y| i * 100 + y).collect())),
        );
        // Tenant 0 loads lines 0..16 on ports 0-1; tenant 1 loads lines
        // 16..32 on ports 2-3, simultaneously.
        let s0 = partition(&[Region { base: 0, lines: 16 }], 2);
        let s1 = partition(&[Region { base: 16, lines: 16 }], 2);
        sys.lps[0].begin_layer(&s0, 1);
        sys.lps[1].begin_layer(&s1, 1);
        sys.run_until_compute_done(200_000).unwrap();
        for (t, scheds) in [(0usize, &s0), (1usize, &s1)] {
            for (p, sched) in scheds.iter().enumerate() {
                let mut expect = Vec::new();
                for r in &sched.runs {
                    for a in r.base..r.end() {
                        for y in 0..n as u64 {
                            expect.push(a * 100 + y);
                        }
                    }
                }
                assert_eq!(sys.lps[t].loaded(p), &expect[..], "tenant {t} port {p}");
            }
        }
    }

    #[test]
    fn out_of_bounds_port_group_rejected() {
        use crate::accel::layer_processor::PortGroup;
        let g = PortGroup { read_base: 3, read_ports: 2, write_base: 0, write_ports: 4 };
        assert!(System::builder(small_cfg(Design::Medusa)).port_groups(&[g]).build().is_err());
    }

    #[test]
    fn builder_matches_positional_construction() {
        use crate::accel::layer_processor::PortGroup;
        // The deprecated shim and the builder must construct the same
        // system (groups, fault spec, clocks).
        let groups = [
            PortGroup { read_base: 0, read_ports: 2, write_base: 0, write_ports: 2 },
            PortGroup { read_base: 2, read_ports: 2, write_base: 2, write_ports: 2 },
        ];
        let spec =
            crate::fault::FaultSpec::parse_cli("dram_refresh=64/8,seed=3").unwrap();
        let built = System::builder(small_cfg(Design::Medusa))
            .port_groups(&groups)
            .faults(&spec)
            .build()
            .unwrap();
        #[allow(deprecated)]
        let mut old = System::new_with_groups(small_cfg(Design::Medusa), &groups).unwrap();
        old.install_faults(&spec).unwrap();
        assert_eq!(built.lps.len(), old.lps.len());
        assert_eq!(built.fault_spec(), old.fault_spec());
        assert_eq!(built.fabric_mhz, old.fabric_mhz);
        // Default builder covers the full fabric with one group.
        let full = System::builder(small_cfg(Design::Medusa)).build().unwrap();
        assert_eq!(full.lps.len(), 1);
        assert!(full.fault_spec().is_none());
    }

    #[test]
    fn timing_model_gates_unbuildable_designs() {
        // A baseline design point in the 1024-bit region fails timing;
        // System::new must refuse it when no clock is pinned.
        let dp = DesignPoint::fig6_step(Design::Baseline, 9);
        let cfg = SystemConfig {
            design: Design::Baseline,
            geometry: dp.geometry,
            dotprod_units: dp.dpus,
            fabric_clock_mhz: None,
            ..small_cfg(Design::Baseline)
        };
        assert!(System::new(cfg).is_err());
    }
}
