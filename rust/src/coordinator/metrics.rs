//! Per-layer and per-run reports: the numbers the end-to-end examples
//! print and EXPERIMENTS.md records.

use std::fmt;

/// Measured execution of one layer through the simulated system.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub layer: &'static str,
    /// Fabric cycles spent loading ifmap + weights.
    pub load_cycles: u64,
    /// Fabric cycles of modelled MAC-array busy time.
    pub compute_cycles: u64,
    /// Fabric cycles draining the ofmap (incl. write flush).
    pub drain_cycles: u64,
    /// Lines moved in / out.
    pub lines_read: u64,
    pub lines_written: u64,
    /// Wall-clock simulated time (ps) for the layer.
    pub sim_time_ps: u64,
    /// Did the output match the golden model bit-for-bit?
    pub verified: bool,
}

impl LayerReport {
    pub fn total_cycles(&self) -> u64 {
        self.load_cycles + self.compute_cycles + self.drain_cycles
    }

    /// Fraction of load cycles in which the memory system delivered at
    /// full port rate (1.0 = interconnect kept every port fed).
    pub fn read_bandwidth_utilization(&self, read_ports: usize, words_per_line: usize) -> f64 {
        if self.load_cycles == 0 {
            return 1.0;
        }
        let words = self.lines_read as f64 * words_per_line as f64;
        let ideal_cycles = words / read_ports as f64;
        (ideal_cycles / self.load_cycles as f64).min(1.0)
    }
}

/// A full inference run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub network: &'static str,
    pub design: &'static str,
    pub fabric_mhz: f64,
    pub layers: Vec<LayerReport>,
}

impl RunReport {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.total_cycles()).sum()
    }

    pub fn total_time_ms(&self) -> f64 {
        self.layers.iter().map(|l| l.sim_time_ps).sum::<u64>() as f64 / 1e9
    }

    pub fn all_verified(&self) -> bool {
        self.layers.iter().all(|l| l.verified)
    }

    pub fn total_lines_moved(&self) -> u64 {
        self.layers.iter().map(|l| l.lines_read + l.lines_written).sum()
    }

    /// Effective DRAM bandwidth achieved (GB/s) over the whole run.
    pub fn effective_bandwidth_gbs(&self, w_line_bits: usize) -> f64 {
        let bytes = self.total_lines_moved() as f64 * w_line_bits as f64 / 8.0;
        let secs = self.total_time_ms() / 1e3;
        if secs == 0.0 {
            0.0
        } else {
            bytes / secs / 1e9
        }
    }
}

impl fmt::Display for RunReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "run: {} on {} interconnect @ {:.0} MHz fabric",
            self.network, self.design, self.fabric_mhz
        )?;
        writeln!(
            f,
            "{:<10} {:>10} {:>10} {:>10} {:>9} {:>9} {:>9}  ok",
            "layer", "load_cyc", "comp_cyc", "drain_cyc", "rd_lines", "wr_lines", "time_us"
        )?;
        for l in &self.layers {
            writeln!(
                f,
                "{:<10} {:>10} {:>10} {:>10} {:>9} {:>9} {:>9.1}  {}",
                l.layer,
                l.load_cycles,
                l.compute_cycles,
                l.drain_cycles,
                l.lines_read,
                l.lines_written,
                l.sim_time_ps as f64 / 1e6,
                if l.verified { "✓" } else { "✗" }
            )?;
        }
        writeln!(
            f,
            "total: {} fabric cycles, {:.3} ms simulated, {:.2} GB/s effective",
            self.total_cycles(),
            self.total_time_ms(),
            self.effective_bandwidth_gbs(512)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(load: u64, lines: u64) -> LayerReport {
        LayerReport {
            layer: "t",
            load_cycles: load,
            compute_cycles: 10,
            drain_cycles: 5,
            lines_read: lines,
            lines_written: 2,
            sim_time_ps: 1_000_000,
            verified: true,
        }
    }

    #[test]
    fn utilization_full_rate_is_one() {
        // 4 ports, 4 words/line: 16 lines = 64 words at 4 words/cycle =
        // 16 ideal cycles.
        let l = layer(16, 16);
        assert!((l.read_bandwidth_utilization(4, 4) - 1.0).abs() < 1e-9);
        let stalled = layer(32, 16);
        assert!((stalled.read_bandwidth_utilization(4, 4) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn run_report_aggregates() {
        let r = RunReport {
            network: "tiny",
            design: "medusa",
            fabric_mhz: 200.0,
            layers: vec![layer(16, 16), layer(20, 8)],
        };
        assert_eq!(r.total_cycles(), 16 + 15 + 20 + 15);
        assert_eq!(r.total_lines_moved(), 16 + 2 + 8 + 2);
        assert!(r.all_verified());
        assert!(r.effective_bandwidth_gbs(512) > 0.0);
        let s = format!("{r}");
        assert!(s.contains("medusa"));
    }
}
