//! Inference driver: runs a whole [`Network`] through the simulated
//! system layer by layer — every tensor byte travels through the
//! interconnect under test, the math runs on the chosen backend, and
//! every layer's output is verified against the Q8.8 golden model and
//! against what actually landed in simulated DRAM.

use crate::accel::dnn::{ConvLayer, Network};
use crate::accel::golden::conv2d_q88;
use crate::accel::prefetch::{partition, Region, TensorMap};
use crate::accel::quant::Fixed16;
use crate::config::SystemConfig;
use crate::coordinator::metrics::{LayerReport, RunReport};
use crate::coordinator::System;
use crate::runtime::ConvExecutor;
use crate::types::{Line, LineAddr, Word};
use crate::util::Prng;
use anyhow::{Context, Result};
use std::collections::VecDeque;

/// Who does the arithmetic.
pub enum ComputeBackend {
    /// Pure-Rust Q8.8 golden model (always available).
    Golden,
    /// The AOT-compiled JAX/Pallas artifact via PJRT. Results are
    /// cross-checked against the golden model per layer.
    Pjrt(Box<ConvExecutor>),
}

impl ComputeBackend {
    pub fn name(&self) -> &'static str {
        match self {
            ComputeBackend::Golden => "golden",
            ComputeBackend::Pjrt(_) => "pjrt",
        }
    }
}

/// Deterministic Q8.8 test weights for a (possibly grouped) conv layer:
/// 1/sqrt(fan-in) scale so receptive fields stay well within range
/// (realistic trained-net scale). THE one generator — the legacy
/// inference driver (`groups == 1`) and the workload scenario engine
/// both route through here so their workload data cannot drift apart.
pub fn gen_conv_weights(
    prng: &mut Prng,
    layer: &ConvLayer,
    groups: usize,
) -> (Vec<Fixed16>, Vec<Fixed16>) {
    let icg = layer.in_c / groups;
    let wcount = layer.out_c * icg * layer.k * layer.k;
    let scale = 1.0 / (icg as f32 * layer.k as f32 * layer.k as f32).sqrt();
    let weights = (0..wcount)
        .map(|_| Fixed16::from_f32((prng.f64() as f32 * 2.0 - 1.0) * scale))
        .collect();
    let bias = (0..layer.out_c)
        .map(|_| Fixed16::from_f32((prng.f64() as f32 * 2.0 - 1.0) * 0.25))
        .collect();
    (weights, bias)
}

pub struct InferenceDriver {
    pub sys: System,
    backend: ComputeBackend,
    /// Next free DRAM line.
    alloc: LineAddr,
}

impl InferenceDriver {
    pub fn new(cfg: SystemConfig, backend: ComputeBackend) -> Result<Self> {
        // Inference exists to produce (and golden-check) real feature
        // maps; a payload-elided fabric retains no loaded words, so an
        // elided config would only fail later, deep in run_layer, with
        // an opaque panic. Refuse it up front instead. (Edge leaping is
        // payload-preserving and fine here.)
        anyhow::ensure!(
            !cfg.sim.payload.is_elided(),
            "InferenceDriver needs full payload (sim.payload = \"elided\" computes no data); \
             use the workload scenario engine for elided runs"
        );
        let sys = System::new(cfg)?;
        Ok(InferenceDriver { sys, backend, alloc: 0 })
    }

    fn words_per_line(&self) -> usize {
        self.sys.cfg.geometry.words_per_line()
    }

    fn alloc_lines(&mut self, words: usize) -> Region {
        let lines = words.div_ceil(self.words_per_line());
        let r = Region { base: self.alloc, lines };
        self.alloc += lines as u64;
        r
    }

    /// Pack quantized words into lines (zero padded) and preload them.
    fn preload_words(&mut self, region: Region, data: &[Fixed16]) {
        let n = self.words_per_line();
        let mut lines = Vec::with_capacity(region.lines);
        for li in 0..region.lines {
            let mut line = Line::zeroed(n);
            for y in 0..n {
                let idx = li * n + y;
                if idx < data.len() {
                    line.set_word(y, data[idx].to_word());
                }
            }
            lines.push(line);
        }
        self.sys.controller_mut().preload(region.base, lines);
    }

    /// Allocate a fresh line region and upload `data` to simulated DRAM
    /// (the tensor-upload path examples and tests use).
    pub fn alloc_and_preload(&mut self, data: &[Fixed16]) -> Region {
        let region = self.alloc_lines(data.len());
        self.preload_words(region, data);
        region
    }

    /// Deterministic Q8.8 test weights: small magnitudes so receptive
    /// fields stay well within range (realistic trained-net scale).
    pub fn gen_weights(prng: &mut Prng, layer: &ConvLayer) -> (Vec<Fixed16>, Vec<Fixed16>) {
        gen_conv_weights(prng, layer, 1)
    }

    /// Run one layer whose input already lives at `ifmap_region`;
    /// returns (report, ofmap region, computed ofmap).
    pub fn run_layer(
        &mut self,
        layer: &ConvLayer,
        ifmap_region: Region,
        weights: &[Fixed16],
        bias: &[Fixed16],
    ) -> Result<(LayerReport, Region, Vec<Fixed16>)> {
        let n = self.words_per_line();
        let geom = self.sys.cfg.geometry;
        // Weights (+bias appended) and ofmap get fresh regions.
        let wregion = self.alloc_lines(layer.weight_words());
        let ofmap_region = self.alloc_lines(layer.ofmap_words());
        let mut wdata: Vec<Fixed16> = weights.to_vec();
        wdata.extend_from_slice(bias);
        self.preload_words(wregion, &wdata);

        let map = TensorMap { ifmap: ifmap_region, weights: wregion, ofmap: ofmap_region };
        let read_scheds = partition(&[map.ifmap, map.weights], geom.read_ports);
        let write_scheds = partition(&[map.ofmap], geom.write_ports);

        let t0 = self.sys.now_ps();
        let load0 = self.sys.lp().load_cycles;
        let comp0 = self.sys.lp().compute_cycles;
        let drain0 = self.sys.lp().drain_cycles;

        // --- Load phase + compute stall.
        self.sys.lp_mut().begin_layer(&read_scheds, layer.macs());
        let total_read_lines = (map.ifmap.lines + map.weights.lines) as u64;
        let budget = 64 * (total_read_lines + 64) * n as u64 + layer.macs() / 8 + 10_000;
        self.sys.run_until_compute_done(budget).with_context(|| format!("layer {}", layer.name))?;

        // --- Reassemble the loaded tensors from the port streams.
        let line_map = {
            let lp = self.sys.lp();
            self.sys.reassemble(&read_scheds, |p| lp.loaded(p).to_vec())
        };
        let extract = |region: Region, words: usize| -> Vec<Fixed16> {
            let mut out = Vec::with_capacity(words);
            'outer: for a in region.base..region.end() {
                let line = &line_map[&a];
                for &w in line {
                    if out.len() == words {
                        break 'outer;
                    }
                    out.push(Fixed16::from_word(w));
                }
            }
            out
        };
        let ifmap = extract(map.ifmap, layer.ifmap_words());
        let loaded_w = extract(map.weights, layer.weight_words());
        let (lw, lb) = loaded_w.split_at(layer.weight_words() - layer.out_c);

        // --- Compute on the backend; always cross-check vs golden.
        let golden = conv2d_q88(layer, &ifmap, lw, lb);
        let (ofmap, backend_ok) = match &mut self.backend {
            ComputeBackend::Golden => (golden.clone(), true),
            ComputeBackend::Pjrt(exec) => {
                let got = exec.run_conv(layer.name, &ifmap, lw, lb)?;
                let ok = got == golden;
                (got, ok)
            }
        };

        // --- Drain phase: pad ofmap to line boundary, split per port.
        let mut padded: Vec<Word> = ofmap.iter().map(|v| v.to_word()).collect();
        padded.resize(ofmap_region.lines * n, 0);
        let data_per_port: Vec<VecDeque<Word>> = write_scheds
            .iter()
            .map(|s| {
                let mut q = VecDeque::new();
                for run in &s.runs {
                    for a in run.base..run.end() {
                        let off = ((a - ofmap_region.base) as usize) * n;
                        q.extend(&padded[off..off + n]);
                    }
                }
                q
            })
            .collect();
        self.sys.lp_mut().supply_output(&write_scheds, data_per_port);
        let drain_budget = 64 * (ofmap_region.lines as u64 + 64) * n as u64 + 10_000;
        self.sys.run_until_drained(drain_budget).with_context(|| format!("layer {}", layer.name))?;

        // --- Verify what actually landed in DRAM.
        let dumped = self.sys.controller().dump(ofmap_region.base, ofmap_region.lines);
        let mut dram_words: Vec<Word> = Vec::with_capacity(padded.len());
        for l in &dumped {
            dram_words.extend_from_slice(l.words());
        }
        let dram_ok = dram_words == padded;

        let report = LayerReport {
            layer: layer.name,
            load_cycles: self.sys.lp().load_cycles - load0,
            compute_cycles: self.sys.lp().compute_cycles - comp0,
            drain_cycles: self.sys.lp().drain_cycles - drain0,
            lines_read: total_read_lines,
            lines_written: ofmap_region.lines as u64,
            sim_time_ps: self.sys.now_ps() - t0,
            verified: backend_ok && dram_ok,
        };
        Ok((report, ofmap_region, ofmap))
    }

    /// Run a whole network on `input`; returns the run report and the
    /// final feature map.
    pub fn run(&mut self, net: &Network, input: &[Fixed16]) -> Result<(RunReport, Vec<Fixed16>)> {
        net.validate()?;
        anyhow::ensure!(
            input.len() == net.layers[0].ifmap_words(),
            "input size {} != layer0 ifmap {}",
            input.len(),
            net.layers[0].ifmap_words()
        );
        let mut prng = Prng::new(self.sys.cfg.seed);
        let mut report = RunReport {
            network: net.name,
            design: self.sys.cfg.design.name(),
            fabric_mhz: self.sys.fabric_mhz,
            layers: Vec::new(),
        };
        // Upload the network input.
        let mut cur_region = self.alloc_lines(input.len());
        self.preload_words(cur_region, input);
        let mut cur_map: Vec<Fixed16> = input.to_vec();
        for layer in &net.layers {
            let (weights, bias) = Self::gen_weights(&mut prng, layer);
            let (lr, ofr, ofmap) = self.run_layer(layer, cur_region, &weights, &bias)?;
            report.layers.push(lr);
            cur_region = ofr;
            cur_map = ofmap;
        }
        Ok((report, cur_map))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::Design;
    use crate::types::Geometry;

    fn cfg(design: Design) -> SystemConfig {
        SystemConfig {
            design,
            geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
            dotprod_units: 8,
            mem_clock_mhz: 200.0,
            fabric_clock_mhz: Some(200.0),
            ddr3_timing: false,
            rotator_stages: 0,
            channel_depths: Default::default(),
            seed: 11,
            sim: Default::default(),
        }
    }

    fn tiny_layer() -> ConvLayer {
        ConvLayer { name: "t", in_c: 2, in_h: 8, in_w: 8, out_c: 4, k: 3, stride: 1, pad: 1, relu: true }
    }

    #[test]
    fn single_layer_verified_on_both_designs() {
        for design in [Design::Medusa, Design::Baseline] {
            let mut drv = InferenceDriver::new(cfg(design), ComputeBackend::Golden).unwrap();
            let layer = tiny_layer();
            let input: Vec<Fixed16> =
                (0..layer.ifmap_words()).map(|i| Fixed16::from_f32((i % 13) as f32 * 0.125 - 0.75)).collect();
            let region = drv.alloc_lines(input.len());
            drv.preload_words(region, &input);
            let mut prng = Prng::new(3);
            let (w, b) = InferenceDriver::gen_weights(&mut prng, &layer);
            let (rep, _, ofmap) = drv.run_layer(&layer, region, &w, &b).unwrap();
            assert!(rep.verified, "{design:?}: layer must verify");
            assert_eq!(ofmap.len(), layer.ofmap_words());
            assert!(rep.load_cycles > 0 && rep.drain_cycles > 0);
            // Cross-design determinism: golden math is design-independent.
            let golden = conv2d_q88(&layer, &input, &w, &b);
            assert_eq!(ofmap, golden);
        }
    }

    #[test]
    fn designs_move_identical_data() {
        // §III-F: Medusa is a drop-in replacement — same network, same
        // seed, same final feature map on both interconnects.
        let net = Network::tiny_vgg();
        let input: Vec<Fixed16> =
            (0..net.layers[0].ifmap_words()).map(|i| Fixed16::from_f32(((i % 29) as f32 - 14.0) / 32.0)).collect();
        let mut out = Vec::new();
        for design in [Design::Medusa, Design::Baseline] {
            let mut drv = InferenceDriver::new(cfg(design), ComputeBackend::Golden).unwrap();
            let (rep, fm) = drv.run(&net, &input).unwrap();
            assert!(rep.all_verified(), "{design:?}");
            out.push(fm);
        }
        assert_eq!(out[0], out[1], "interconnects must be data-transparent");
    }
}
