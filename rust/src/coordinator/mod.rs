//! The L3 coordinator: assembles the full system — layer processor,
//! interconnect under test, request arbiter, CDC channels, and the DDR3
//! controller in its own clock domain — and drives complete DNN
//! inference passes through it.
//!
//! This is the paper's system context (§IV-C): a convolutional layer
//! processor using all narrow ports of the interconnect, a 512-bit
//! 200 MHz DDR3 controller interface, and the interconnect as the only
//! thing between them. The coordinator owns the event loop; compute is
//! delegated to a [`crate::coordinator::driver::ComputeBackend`] (Rust
//! golden model or the AOT-compiled JAX/Pallas artifact via PJRT).

pub mod driver;
pub mod metrics;
pub mod system;

pub use driver::{ComputeBackend, InferenceDriver};
pub use metrics::{LayerReport, RunReport};
pub use system::{System, SystemBuilder};
