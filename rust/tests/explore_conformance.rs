//! Conformance suite for the hybrid interconnect family and the
//! design-space explorer (PR 4).
//!
//! What it locks down:
//!
//! * the family endpoints are *bit-for-bit* the endpoint designs at the
//!   system level: for every zoo network, a radix-2 hybrid run has the
//!   same fingerprint (every stat counter, cycle count, per-port wait,
//!   final feature map) and the same DRAM-delivered bytes as `baseline`,
//!   and a radix-N hybrid run the same as `medusa`;
//! * intermediate radices run every zoo network golden-verified and
//!   deliver identical data (the whole family is data-transparent);
//! * hybrid runs capture and replay through the canonical trace format
//!   (the spec string round-trips through the header);
//! * explorer searches are deterministic: sequential vs parallel and
//!   cold-cache vs warm-cache runs produce identical evaluated sets and
//!   identical Pareto frontiers, with the warm run answered entirely
//!   from the cache;
//! * the default grid meets the ≥ 100 design-point floor.

use medusa::config::SystemConfig;
use medusa::explore::{point_key, run_search, DesignSpace, ExploreCache, Strategy};
use medusa::interconnect::hybrid::HybridConfig;
use medusa::interconnect::Design;
use medusa::types::Geometry;
use medusa::workload::{self, zoo, Scenario};

/// The conformance geometry: N = 8 words/line, so radix 2 and radix 8
/// are the family endpoints and radix 4 is a genuine intermediate.
fn cfg(design: Design) -> SystemConfig {
    SystemConfig {
        design,
        geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
        dotprod_units: 16,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: Some(200.0),
        ddr3_timing: false,
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 7,
        sim: Default::default(),
    }
}

fn hybrid(radix: usize) -> Design {
    Design::Hybrid(HybridConfig { transpose_radix: radix, ..HybridConfig::default() })
}

fn run_single(name: &str, design: Design, net: workload::WorkloadNet) -> workload::ScenarioOutcome {
    let sc = Scenario::single(name, cfg(design), net);
    workload::run_scenario(&sc).unwrap_or_else(|e| panic!("{name} on {design:?}: {e:#}"))
}

#[test]
fn hybrid_endpoints_are_bit_identical_to_endpoint_designs_on_every_zoo_network() {
    for net in zoo::all() {
        for (radix, partner) in [(2usize, Design::Baseline), (8, Design::Medusa)] {
            let h = run_single(&format!("hx-{}", net.name), hybrid(radix), net.clone());
            let p = run_single(&format!("hx-{}", net.name), partner, net.clone());
            assert!(h.all_verified(), "{} radix {radix}", net.name);
            // Full-outcome fingerprint: every counter in the registry,
            // cycle counts, per-port waits, final feature maps.
            assert_eq!(
                h.fingerprint(),
                p.fingerprint(),
                "{}: radix-{radix} hybrid not stat-identical to {partner:?}",
                net.name
            );
            // And the words the fabric actually landed in DRAM.
            assert_eq!(
                h.tenants[0].final_dram, p.tenants[0].final_dram,
                "{}: radix-{radix} hybrid delivered different DRAM bytes than {partner:?}",
                net.name
            );
        }
    }
}

#[test]
fn intermediate_radix_runs_every_zoo_network_with_identical_data() {
    for net in zoo::all() {
        let mid = run_single(&format!("mid-{}", net.name), hybrid(4), net.clone());
        assert!(mid.all_verified(), "{} on radix-4 hybrid", net.name);
        let med = run_single(&format!("mid-{}", net.name), Design::Medusa, net.clone());
        // Data transparency across the family: same DRAM bytes, even
        // though timing (and therefore fingerprints) may differ.
        assert_eq!(
            mid.tenants[0].final_dram, med.tenants[0].final_dram,
            "{}: intermediate radix broke data transparency",
            net.name
        );
        // The intermediate datapath really ran (its counters moved).
        assert!(
            mid.stats.get("hybrid_read.lines_transposed") > 0
                && mid.stats.get("hybrid_write.lines_transposed") > 0,
            "{}: partial-transpose counters untouched",
            net.name
        );
        assert_eq!(mid.stats.get("medusa_read.lines_transposed"), 0, "{}", net.name);
    }
}

#[test]
fn multi_tenant_scenarios_match_across_family_endpoints() {
    for (radix, partner) in [(2usize, Design::Baseline), (8, Design::Medusa)] {
        let mut h = Scenario::builtin("multi-tenant-mix").unwrap();
        h.cfg.design = hybrid(radix);
        let mut p = Scenario::builtin("multi-tenant-mix").unwrap();
        p.cfg.design = partner;
        let ho = workload::run_scenario(&h).unwrap();
        let po = workload::run_scenario(&p).unwrap();
        assert!(ho.all_verified());
        assert_eq!(ho.fingerprint(), po.fingerprint(), "radix {radix} vs {partner:?}");
    }
}

#[test]
fn hybrid_trace_captures_and_replays_through_the_spec_string() {
    // An intermediate radix: the header must carry "hybrid:r4:s0:g1"
    // and replay must rebuild that exact datapath and reproduce every
    // recorded counter and cycle count.
    let sc = Scenario::single("hx-trace", cfg(hybrid(4)), zoo::gemm_mlp());
    let (out, trace) = workload::run_scenario_captured(&sc).unwrap();
    assert!(out.all_verified());
    assert_eq!(trace.header.design, "hybrid:r4:s0:g1");
    let replayed = workload::verify_replay(&trace).unwrap();
    assert_eq!(replayed.fabric_cycles, out.fabric_cycles);
    // Round-trip through the on-disk text form too.
    let text = trace.to_text();
    let back = medusa::sim::trace::ScenarioTrace::from_str(&text).unwrap();
    workload::verify_replay(&back).unwrap();
}

#[test]
fn explorer_is_deterministic_sequential_vs_parallel() {
    let space = DesignSpace::smoke();
    let seq = run_search(&space, &Strategy::Grid, 1, 1, None).unwrap();
    let par = run_search(&space, &Strategy::Grid, 1, 8, None).unwrap();
    assert_eq!(seq.evaluated, par.evaluated, "thread count changed explorer results");
    let fs: Vec<usize> = seq.frontier.iter().map(|e| e.index).collect();
    let fp: Vec<usize> = par.frontier.iter().map(|e| e.index).collect();
    assert_eq!(fs, fp, "thread count changed the Pareto frontier");
    assert!(!seq.frontier.is_empty());
    // Feasible points all golden-verified their probe runs.
    assert!(seq.evaluated.iter().all(|(_, m)| !m.feasible() || m.verified));
}

#[test]
fn explorer_cache_hit_equals_recompute() {
    let path = std::env::temp_dir()
        .join(format!("medusa-explore-conformance-{}.cache", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let space = DesignSpace::smoke();

    let mut cache = ExploreCache::open(&path);
    let cold = run_search(&space, &Strategy::Grid, 1, 4, Some(&mut cache)).unwrap();
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.computed, cold.evaluated.len());

    // Fresh handle: everything must come back from disk, bit-identical.
    let mut cache = ExploreCache::open(&path);
    assert_eq!(cache.len(), cold.evaluated.len());
    let warm = run_search(&space, &Strategy::Grid, 1, 4, Some(&mut cache)).unwrap();
    assert_eq!(warm.cache_hits, warm.evaluated.len(), "warm run must be pure cache reads");
    assert_eq!(warm.computed, 0);
    assert_eq!(cold.evaluated, warm.evaluated, "cache round-trip changed results");
    let fc: Vec<usize> = cold.frontier.iter().map(|e| e.index).collect();
    let fw: Vec<usize> = warm.frontier.iter().map(|e| e.index).collect();
    assert_eq!(fc, fw, "cache round-trip changed the frontier");

    // Cache keys are stable across runs (the incremental contract).
    // `run_search` evaluates with the fast backend, so entries live
    // under the elided payload key.
    let pts = space.points();
    for p in &pts {
        assert!(
            cache
                .get(point_key(p, &space.probe, medusa::config::PayloadMode::Elided, None))
                .is_some(),
            "missing entry {}",
            p.label()
        );
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn default_grid_meets_the_acceptance_floor() {
    let pts = DesignSpace::default_grid().points();
    assert!(pts.len() >= 100, "default grid: {} points (acceptance floor is 100)", pts.len());
    // It spans the required port range and contains the whole family.
    assert!(pts.iter().any(|p| p.geometry.read_ports == 4));
    assert!(pts.iter().any(|p| p.geometry.read_ports == 64));
    assert!(pts.iter().any(|p| p.design == Design::Baseline));
    assert!(pts.iter().any(|p| p.design == Design::Medusa));
    assert!(pts
        .iter()
        .any(|p| matches!(p.design, Design::Hybrid(hc) if hc.stage_pipelining > 0)));
}

#[test]
fn seeded_strategies_are_reproducible() {
    let space = DesignSpace::smoke();
    for strat in [
        Strategy::Random { samples: 4 },
        Strategy::HillClimb { restarts: 2, steps: 3 },
    ] {
        let a = run_search(&space, &strat, 99, 4, None).unwrap();
        let b = run_search(&space, &strat, 99, 1, None).unwrap();
        assert_eq!(a.evaluated, b.evaluated, "{strat:?} not reproducible");
    }
}
