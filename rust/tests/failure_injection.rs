//! Failure injection & robustness: random stalls on every boundary of
//! the data-transfer networks (memory-side delivery, port-side
//! consumption, memory-side drain), arbiter policy ablation, and burst
//! configuration sweeps. The invariant under all of it: data is never
//! lost, duplicated, or reordered.

use medusa::interconnect::arbiter::{Arbiter, MemCommand, Policy};
use medusa::interconnect::harness::gen_lines;
use medusa::interconnect::{build_read_network, build_write_network, Design};
use medusa::sim::{Channel, Stats};
use medusa::types::{Geometry, ReadRequest, Word, WriteRequest};
use medusa::util::Prng;

fn geom(ports: usize, w_line: usize, burst: usize) -> Geometry {
    Geometry { w_line, w_acc: 16, read_ports: ports, write_ports: ports, max_burst: burst }
}

/// Read path under random stall storms on both sides.
#[test]
fn read_integrity_under_random_stalls() {
    for design in [Design::Baseline, Design::Medusa, Design::Axis] {
        for stall_p in [0.1, 0.5, 0.9] {
            let g = geom(8, 128, 4);
            let lines = gen_lines(&g, 96, 11);
            let mut net = build_read_network(design, g);
            let mut stats = Stats::new();
            let mut prng = Prng::new(0xfa11 ^ (stall_p * 100.0) as u64);
            let mut got: Vec<Vec<Word>> = vec![Vec::new(); g.read_ports];
            let mut next = 0usize;
            let total_words = lines.len() * g.words_per_line();
            let mut popped = 0usize;
            let mut cycles = 0u64;
            while popped < total_words {
                net.tick(cycles, &mut stats);
                // Memory side stalls randomly (a DRAM controller under
                // bank conflicts / refresh).
                if next < lines.len() && !prng.chance(stall_p) && net.mem_can_deliver(lines[next].port)
                {
                    net.mem_deliver(lines[next].clone());
                    next += 1;
                }
                // Ports stall randomly (layer processor busy).
                for p in 0..g.read_ports {
                    if !prng.chance(stall_p) && net.port_word_available(p) {
                        got[p].push(net.port_take_word(p).unwrap());
                        popped += 1;
                    }
                }
                cycles += 1;
                assert!(cycles < 3_000_000, "{design:?}@{stall_p}: livelock");
            }
            for p in 0..g.read_ports {
                let expect: Vec<Word> = lines
                    .iter()
                    .filter(|l| l.port == p)
                    .flat_map(|l| l.line.words().to_vec())
                    .collect();
                assert_eq!(got[p], expect, "{design:?}@{stall_p} port {p}");
            }
        }
    }
}

/// Write path with the memory side drained erratically.
#[test]
fn write_integrity_under_erratic_drain() {
    for design in [Design::Baseline, Design::Medusa] {
        let g = geom(4, 64, 2);
        let n = g.words_per_line();
        let mut net = build_write_network(design, g);
        let mut stats = Stats::new();
        let mut prng = Prng::new(77);
        let lines_per_port = 12usize;
        let mut sent = vec![0usize; g.write_ports];
        let mut got: Vec<Vec<Word>> = vec![Vec::new(); g.write_ports];
        let mut taken = 0usize;
        let mut cycles = 0u64;
        while taken < lines_per_port * g.write_ports {
            net.tick(cycles, &mut stats);
            // Drain only 30% of cycles, random port order.
            if prng.chance(0.3) {
                let start = prng.range(0, g.write_ports - 1);
                for k in 0..g.write_ports {
                    let p = (start + k) % g.write_ports;
                    if net.mem_lines_ready(p) > 0 {
                        got[p].extend(net.mem_take_line(p).unwrap().words().to_vec());
                        taken += 1;
                        break;
                    }
                }
            }
            for p in 0..g.write_ports {
                if sent[p] < lines_per_port * n && net.port_can_accept(p) {
                    net.port_push_word(p, (p * 100_000 + sent[p]) as Word & g.word_mask());
                    sent[p] += 1;
                }
            }
            cycles += 1;
            assert!(cycles < 1_000_000, "{design:?}: livelock");
        }
        for p in 0..g.write_ports {
            let expect: Vec<Word> =
                (0..lines_per_port * n).map(|i| (p * 100_000 + i) as Word & g.word_mask()).collect();
            assert_eq!(got[p], expect, "{design:?} port {p}");
        }
    }
}

/// Arbiter policy ablation: ReadPriority must starve writes under read
/// pressure but never corrupt anything; RoundRobin must stay fair.
#[test]
fn arbiter_policy_ablation() {
    let g = geom(4, 64, 4);
    let n = g.words_per_line();
    let run = |policy: Policy| -> (u64, u64) {
        let rd = build_read_network(Design::Medusa, g);
        let mut wr = build_write_network(Design::Medusa, g);
        let mut arb = Arbiter::new(4, 4, policy);
        let mut cmd: Channel<MemCommand> = Channel::new("cmd", 2);
        let mut wdata = Channel::new("wdata", 8);
        let mut stats = Stats::new();
        // Preload write data so write requests are always issuable.
        let mut c = 0u64;
        for _ in 0..2 * n {
            wr.tick(c, &mut stats);
            for p in 0..4 {
                if wr.port_can_accept(p) {
                    wr.port_push_word(p, 7);
                }
            }
            c += 1;
        }
        // Saturate both queues, run a fixed window, count grants.
        let (mut reads, mut writes) = (0u64, 0u64);
        for i in 0..64u64 {
            arb.submit_read(ReadRequest { port: (i % 4) as usize, addr: i * 4, burst_len: 1 });
            arb.submit_write(WriteRequest { port: (i % 4) as usize, addr: 512 + i, burst_len: 1 });
        }
        for _ in 0..200 {
            wr.tick(c, &mut stats);
            arb.tick(rd.as_ref(), wr.as_mut(), &mut cmd, &mut wdata, &mut stats);
            cmd.commit();
            wdata.commit();
            while let Some(cmdv) = cmd.pop() {
                match cmdv {
                    MemCommand::Read { .. } => reads += 1,
                    MemCommand::Write { .. } => writes += 1,
                }
            }
            while wdata.pop().is_some() {}
            c += 1;
        }
        (reads, writes)
    };
    let (rr_reads, rr_writes) = run(Policy::RoundRobin);
    let (rp_reads, rp_writes) = run(Policy::ReadPriority);
    // Round-robin alternates grants while both classes are backlogged.
    assert!(rr_reads > 0 && rr_writes > 0);
    let imbalance = (rr_reads as i64 - rr_writes as i64).abs();
    assert!(imbalance <= 8, "round-robin imbalance {rr_reads} vs {rr_writes}");
    // Read-priority issues every queued read before any further write
    // beyond data-driven interleaving.
    assert!(rp_reads >= rr_reads, "{rp_reads} vs {rr_reads}");
    assert!(rp_writes <= rr_writes, "{rp_writes} vs {rr_writes}");
}

/// Burst-length sweep: throughput and integrity must hold for any
/// MaxBurst provisioning (the buffers scale with it, §III-C).
#[test]
fn burst_length_sweep() {
    for burst in [1usize, 2, 4, 16, 32, 64] {
        let g = geom(8, 128, burst);
        let lines = gen_lines(&g, 128, burst as u64);
        for design in [Design::Baseline, Design::Medusa] {
            let mut net = build_read_network(design, g);
            let (res, got) = medusa::interconnect::harness::drive_read(net.as_mut(), &lines, true);
            assert!(
                res.lines_per_cycle() > 0.8,
                "{design:?} burst {burst}: {:.3} lines/cycle",
                res.lines_per_cycle()
            );
            let total: usize = got.iter().map(|v| v.len()).sum();
            assert_eq!(total, 128 * g.words_per_line());
        }
    }
}

/// Word-width sweep: 8-bit ports (the paper's other accelerator width)
/// and wider ones must round-trip too.
#[test]
fn word_width_sweep() {
    for w_acc in [8usize, 16, 32] {
        let n = 8;
        let g = Geometry { w_line: n * w_acc, w_acc, read_ports: n, write_ports: n, max_burst: 4 };
        let lines = gen_lines(&g, 64, w_acc as u64);
        for design in [Design::Baseline, Design::Medusa] {
            let mut net = build_read_network(design, g);
            let (_, got) = medusa::interconnect::harness::drive_read(net.as_mut(), &lines, true);
            for p in 0..n {
                let expect: Vec<Word> = lines
                    .iter()
                    .filter(|l| l.port == p)
                    .flat_map(|l| l.line.words().to_vec())
                    .collect();
                assert_eq!(got[p], expect, "{design:?} w_acc={w_acc} port {p}");
                assert!(got[p].iter().all(|w| *w <= g.word_mask()));
            }
        }
    }
}

/// Back-to-back layers with no settle time between them (the arbiter and
/// networks must be reusable without reset).
#[test]
fn no_reset_between_workloads() {
    let g = geom(4, 64, 4);
    let mut net = build_read_network(Design::Medusa, g);
    for round in 0..5u64 {
        let lines = gen_lines(&g, 32, round);
        let (_, got) = medusa::interconnect::harness::drive_read(net.as_mut(), &lines, true);
        for p in 0..g.read_ports {
            let expect: Vec<Word> = lines
                .iter()
                .filter(|l| l.port == p)
                .flat_map(|l| l.line.words().to_vec())
                .collect();
            assert_eq!(got[p], expect, "round {round} port {p}");
        }
    }
}
