//! Calibration lock: every cell of the paper's Tables I/II and every
//! qualitative claim of Fig 6, asserted against the models. If a model
//! change drifts outside tolerance, this suite fails — the reproduction
//! contract in executable form. EXPERIMENTS.md records the same numbers.

use medusa::eval::{fig6, table1, table2};
use medusa::fpga::resources::{
    axis_read, axis_write, baseline_read, baseline_write, full_design, medusa_read, medusa_write,
};
use medusa::interconnect::Design;

fn pct_err(model: u64, paper: u64) -> f64 {
    100.0 * (model as f64 - paper as f64) / paper as f64
}

#[test]
fn table1_every_cell_within_15pct() {
    let g = table1::geometry();
    let model = [
        (baseline_read(&g).lut, baseline_read(&g).ff),
        (axis_read(&g).lut, axis_read(&g).ff),
        (baseline_write(&g).lut, baseline_write(&g).ff),
        (axis_write(&g).lut, axis_write(&g).ff),
    ];
    for ((name, plut, pff), (mlut, mff)) in table1::PAPER.iter().zip(model.iter()) {
        let le = pct_err(*mlut, *plut);
        let fe = pct_err(*mff, *pff);
        assert!(le.abs() <= 15.0, "{name} LUT: model {mlut} vs paper {plut} ({le:+.1}%)");
        assert!(fe.abs() <= 15.0, "{name} FF: model {mff} vs paper {pff} ({fe:+.1}%)");
    }
}

#[test]
fn table2_network_cells_within_15pct_and_brams_exact() {
    let g = table2::geometry();
    let cells = [
        ("base read", baseline_read(&g), 18_168u64, 19_210u64, 0u64),
        ("base write", baseline_write(&g), 26_810, 35_451, 0),
        ("medusa read", medusa_read(&g), 4_733, 4_759, 32),
        ("medusa write", medusa_write(&g), 4_777, 4_325, 32),
    ];
    for (name, r, plut, pff, pbram) in cells {
        assert!(pct_err(r.lut, plut).abs() <= 15.0, "{name} LUT {} vs {plut}", r.lut);
        assert!(pct_err(r.ff, pff).abs() <= 15.0, "{name} FF {} vs {pff}", r.ff);
        assert_eq!(r.bram18, pbram, "{name} BRAM");
    }
}

#[test]
fn table2_totals_within_10pct() {
    let g = table2::geometry();
    let base = full_design(Design::Baseline, &g, table2::DPUS);
    let med = full_design(Design::Medusa, &g, table2::DPUS);
    assert!(pct_err(base.lut, 198_887).abs() <= 10.0, "baseline total LUT {}", base.lut);
    assert!(pct_err(base.ff, 240_449).abs() <= 10.0, "baseline total FF {}", base.ff);
    assert!(pct_err(base.bram18, 726).abs() <= 5.0, "baseline total BRAM {}", base.bram18);
    assert_eq!(base.dsp, 2_048);
    assert!(pct_err(med.lut, 156_409).abs() <= 10.0, "medusa total LUT {}", med.lut);
    assert!(pct_err(med.ff, 195_158).abs() <= 10.0, "medusa total FF {}", med.ff);
    assert!(pct_err(med.bram18, 790).abs() <= 5.0, "medusa total BRAM {}", med.bram18);
    assert_eq!(med.dsp, 2_048);
}

#[test]
fn abstract_headline_factors() {
    // "reduce LUT and FF use by 4.7x and 6.0x, and improves frequency by
    // 1.8x" — the three numbers in the abstract.
    let h = table2::headline();
    assert!((3.8..=5.6).contains(&h.lut_factor), "LUT factor {:.2}", h.lut_factor);
    assert!((4.8..=7.2).contains(&h.ff_factor), "FF factor {:.2}", h.ff_factor);
    let pts = fig6::sweep();
    let at_2048 = pts.iter().find(|p| p.dsps == 2048).unwrap();
    let speedup = at_2048.medusa_mhz as f64 / at_2048.baseline_mhz.max(1) as f64;
    assert!(speedup >= 1.8, "frequency speedup at the Table II point: {speedup:.2} (paper 1.8x+)");
}

#[test]
fn fig6_regions_and_crossover() {
    let pts = fig6::sweep();
    assert_eq!(pts.len(), 11);
    // Crossover: baseline >= medusa below 1024 DSPs, medusa >= baseline
    // from 1024 on (§IV-D).
    for p in &pts {
        if p.dsps < 1024 {
            assert!(p.baseline_mhz >= p.medusa_mhz, "{p:?}");
        } else {
            assert!(p.medusa_mhz >= p.baseline_mhz, "{p:?}");
        }
    }
    // 1024-bit region: baseline barely usable / failing; Medusa 200-225.
    for p in pts.iter().filter(|p| p.w_line == 1024) {
        assert!(p.baseline_mhz <= 50, "{p:?}");
        assert!((200..=225).contains(&p.medusa_mhz), "{p:?}");
    }
    assert!(pts.iter().any(|p| p.w_line == 1024 && p.baseline_mhz == 0));
    // Medusa can feed the 200 MHz DDR3 controller at every 512-bit point;
    // the baseline cannot at the larger ones.
    for p in pts.iter().filter(|p| p.w_line == 512) {
        assert!(p.medusa_mhz >= 200, "{p:?}");
    }
    assert!(pts.iter().any(|p| p.w_line == 512 && p.baseline_mhz < 200));
}

#[test]
fn paper_960_bram_claim() {
    // §IV-C: a BRAM-based baseline would need 960 BRAMs (32x512b FIFO =
    // 15 BRAM-18K, x64 FIFOs), vs Medusa's 64.
    use medusa::fpga::resources::bram18_for;
    assert_eq!(bram18_for(512, 32) * 64, 960);
    let g = table2::geometry();
    assert_eq!(medusa_read(&g).bram18 + medusa_write(&g).bram18, 64);
}
