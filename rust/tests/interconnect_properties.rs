//! Property-based invariants over the interconnect designs, using the
//! in-repo shrinking harness (`medusa::testing`). These are the paper's
//! §III data-transfer-characteristics claims as universally quantified
//! statements over random geometries and traffic.

use medusa::interconnect::harness::{drive_read, drive_write, gen_lines};
use medusa::interconnect::{build_read_network, build_write_network, Design};
use medusa::sim::Stats;
use medusa::testing::prop::{check, Config, Gen};
use medusa::types::{Geometry, TaggedLine, Word};
use medusa::util::Prng;

/// A random-but-valid interconnect test case.
#[derive(Clone, Debug)]
struct Case {
    geom: Geometry,
    lines: usize,
    seed: u64,
}

struct CaseGen;

impl Gen<Case> for CaseGen {
    fn generate(&self, rng: &mut Prng) -> Case {
        let n_pow = rng.range(1, 5); // words/line in {2,4,8,16,32}
        let n = 1usize << n_pow;
        let w_acc = 16;
        let w_line = n * w_acc;
        // Ports: anywhere from 1 to N, including non-powers of two (§III-G).
        let ports = rng.range(1, n);
        let max_burst = [1usize, 2, 4, 8, 32][rng.range(0, 4)];
        Case {
            geom: Geometry { w_line, w_acc, read_ports: ports, write_ports: ports, max_burst },
            lines: rng.range(1, 96),
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self, c: &Case) -> Vec<Case> {
        let mut out = Vec::new();
        if c.lines > 1 {
            out.push(Case { lines: c.lines / 2, ..c.clone() });
            out.push(Case { lines: c.lines - 1, ..c.clone() });
        }
        if c.geom.read_ports > 1 {
            let mut g = c.geom;
            g.read_ports -= 1;
            g.write_ports -= 1;
            out.push(Case { geom: g, ..c.clone() });
        }
        if c.geom.w_line > 2 * c.geom.w_acc {
            let mut g = c.geom;
            g.w_line /= 2;
            g.read_ports = g.read_ports.min(g.w_line / g.w_acc);
            g.write_ports = g.read_ports;
            out.push(Case { geom: g, ..c.clone() });
        }
        out
    }
}

fn cfg() -> Config {
    Config { cases: 48, ..Config::default() }
}

/// §III-F + §III-A: for any traffic, each read port receives exactly the
/// words of its own lines, in order — on every design.
#[test]
fn prop_read_data_integrity_all_designs() {
    check(cfg(), &CaseGen, |c: &Case| {
        let lines = gen_lines(&c.geom, c.lines, c.seed);
        for design in [Design::Baseline, Design::Medusa] {
            let mut net = build_read_network(design, c.geom);
            let (_, got) = drive_read(net.as_mut(), &lines, true);
            for p in 0..c.geom.read_ports {
                let expect: Vec<Word> = lines
                    .iter()
                    .filter(|l| l.port == p)
                    .flat_map(|l| l.line.words().to_vec())
                    .collect();
                if got[p] != expect {
                    return Err(format!("{design:?} port {p}: data mismatch"));
                }
            }
        }
        Ok(())
    });
}

/// Write direction: lines leaving the network are exactly the pushed
/// words, re-lined, in order.
#[test]
fn prop_write_data_integrity_all_designs() {
    check(cfg(), &CaseGen, |c: &Case| {
        let lines_per_port = (c.lines / c.geom.write_ports).max(1);
        for design in [Design::Baseline, Design::Medusa] {
            let mut net = build_write_network(design, c.geom);
            let (_, got) = drive_write(net.as_mut(), lines_per_port, c.seed, true);
            let n = c.geom.words_per_line();
            let mut prng = Prng::new(c.seed);
            for p in 0..c.geom.write_ports {
                let expect: Vec<Word> =
                    (0..lines_per_port * n).map(|_| prng.next_u64() & c.geom.word_mask()).collect();
                let flat: Vec<Word> = got[p].iter().flat_map(|l| l.words().to_vec()).collect();
                if flat != expect {
                    return Err(format!("{design:?} port {p}: write data mismatch"));
                }
            }
        }
        Ok(())
    });
}

/// Both designs sustain full aggregate bandwidth when all ports are
/// saturated (§III-A: "capable of processing one W_line-bit line per
/// cycle").
#[test]
fn prop_full_bandwidth_when_saturated() {
    check(cfg(), &CaseGen, |c: &Case| {
        // Saturation needs all ports busy: round-robin traffic, enough of
        // it, and ports == words_per_line.
        let mut g = c.geom;
        g.read_ports = g.words_per_line();
        g.write_ports = g.words_per_line();
        let total = 128.max(g.read_ports * 8);
        let lines = gen_lines(&g, total, c.seed);
        for design in [Design::Baseline, Design::Medusa] {
            let mut net = build_read_network(design, g);
            let (res, _) = drive_read(net.as_mut(), &lines, false);
            if res.lines_per_cycle() < 0.8 {
                return Err(format!(
                    "{design:?}: only {:.3} lines/cycle with {} ports",
                    res.lines_per_cycle(),
                    g.read_ports
                ));
            }
        }
        Ok(())
    });
}

/// §III-E: Medusa's first-word latency exceeds the baseline's by at most
/// the constant `W_line/W_acc (+ activation)` cycles, for any geometry
/// and any arrival phase.
#[test]
fn prop_latency_overhead_bounded() {
    check(cfg(), &CaseGen, |c: &Case| {
        let n = c.geom.words_per_line();
        let port = (c.seed as usize) % c.geom.read_ports;
        let phase = (c.seed >> 8) % 17;
        let latency_of = |design: Design| -> Result<u64, String> {
            let mut net = build_read_network(design, c.geom);
            let mut stats = Stats::new();
            let mut cyc = 0u64;
            for _ in 0..phase {
                net.tick(cyc, &mut stats);
                cyc += 1;
            }
            let line = gen_lines(&c.geom, 1, c.seed).remove(0);
            net.mem_deliver(TaggedLine { port, line: line.line });
            let start = cyc;
            loop {
                net.tick(cyc, &mut stats);
                cyc += 1;
                if net.port_word_available(port) {
                    return Ok(cyc - start);
                }
                if cyc - start > (4 * n + 16) as u64 {
                    return Err(format!("{design:?}: word never arrived"));
                }
            }
        };
        let base = latency_of(Design::Baseline)?;
        let medusa = latency_of(Design::Medusa)?;
        let overhead = medusa.saturating_sub(base);
        if overhead > (n + 2) as u64 {
            return Err(format!(
                "latency overhead {overhead} > N+2 = {} (base {base}, medusa {medusa})",
                n + 2
            ));
        }
        Ok(())
    });
}

/// §III-F: no interference — a port's word-arrival cadence is unchanged
/// by other ports' traffic (Medusa).
#[test]
fn prop_no_interference_medusa() {
    check(Config { cases: 24, ..Config::default() }, &CaseGen, |c: &Case| {
        if c.geom.read_ports < 2 {
            return Ok(());
        }
        let victim = 0usize;
        let cadence = |with_noise: bool| -> Vec<u64> {
            let mut net = build_read_network(Design::Medusa, c.geom);
            let mut stats = Stats::new();
            let mut prng = Prng::new(c.seed);
            let victim_lines: Vec<TaggedLine> = gen_lines(&c.geom, 8, c.seed ^ 1)
                .into_iter()
                .map(|mut l| {
                    l.port = victim;
                    l
                })
                .collect();
            let mut vi = 0usize;
            let mut arrivals = Vec::new();
            for cyc in 0..600u64 {
                net.tick(cyc, &mut stats);
                if vi < victim_lines.len() && net.mem_can_deliver(victim) {
                    net.mem_deliver(victim_lines[vi].clone());
                    vi += 1;
                } else if with_noise {
                    // Random other-port traffic whenever the interface is
                    // free (deterministic given the seed).
                    let p = 1 + (prng.next_u64() as usize) % (c.geom.read_ports - 1);
                    if net.mem_can_deliver(p) {
                        let line = gen_lines(&c.geom, 1, prng.next_u64()).remove(0);
                        net.mem_deliver(TaggedLine { port: p, line: line.line });
                    }
                }
                if net.port_word_available(victim) {
                    net.port_take_word(victim).unwrap();
                    arrivals.push(cyc);
                }
                // Drain noise ports so they keep flowing.
                for p in 1..c.geom.read_ports {
                    if net.port_word_available(p) {
                        net.port_take_word(p).unwrap();
                    }
                }
            }
            arrivals
        };
        let solo = cadence(false);
        let noisy = cadence(true);
        if solo != noisy {
            return Err("victim port cadence changed under other-port traffic".into());
        }
        Ok(())
    });
}

/// Baseline and Medusa are **drop-in interchangeable**: identical traffic
/// yields identical per-port word streams (order included).
#[test]
fn prop_designs_equivalent_streams() {
    check(cfg(), &CaseGen, |c: &Case| {
        let lines = gen_lines(&c.geom, c.lines, c.seed);
        let mut base = build_read_network(Design::Baseline, c.geom);
        let (_, got_b) = drive_read(base.as_mut(), &lines, true);
        let mut med = build_read_network(Design::Medusa, c.geom);
        let (_, got_m) = drive_read(med.as_mut(), &lines, true);
        if got_b != got_m {
            return Err("baseline and medusa delivered different streams".into());
        }
        Ok(())
    });
}
