//! CLI smoke tests: run the `medusa` binary end-to-end and check its
//! surfaces (help, eval regeneration, design-point tools, error paths).

use std::process::Command;

fn medusa(args: &[&str]) -> (bool, String, String) {
    let bin = env!("CARGO_BIN_EXE_medusa");
    let out = Command::new(bin).args(args).output().expect("binary runs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (ok, stdout, _) = medusa(&["help"]);
    assert!(ok);
    for cmd in ["eval", "infer", "resources", "freq", "sweep", "info", "serve"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn no_args_prints_usage_successfully() {
    let (ok, stdout, _) = medusa(&[]);
    assert!(ok);
    assert!(stdout.contains("usage: medusa"));
}

#[test]
fn unknown_subcommand_fails_with_message() {
    let (ok, _, stderr) = medusa(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn eval_table1_prints_paper_comparison() {
    let (ok, stdout, _) = medusa(&["eval", "table1"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("Table I"));
    assert!(stdout.contains("5,313"), "paper column present");
}

#[test]
fn eval_table2_prints_headline() {
    let (ok, stdout, _) = medusa(&["eval", "table2"]);
    assert!(ok);
    assert!(stdout.contains("Medusa Total"));
    assert!(stdout.contains("headline:"));
}

#[test]
fn eval_fig6_prints_regions_and_plot() {
    let (ok, stdout, _) = medusa(&["eval", "fig6"]);
    assert!(ok);
    assert!(stdout.contains("1024-bit"));
    assert!(stdout.contains("memory interface width regions"));
}

#[test]
fn sweep_emits_csv() {
    let (ok, stdout, _) = medusa(&["sweep"]);
    assert!(ok);
    let mut lines = stdout.lines();
    assert!(lines.next().unwrap().starts_with("DSPs,"));
    assert_eq!(lines.count(), 11);
}

#[test]
fn resources_reports_design_point() {
    let (ok, stdout, _) = medusa(&["resources", "--design", "baseline", "--ports", "16"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("baseline"));
    assert!(stdout.contains("utilization"));
}

#[test]
fn freq_reports_peak_or_failure() {
    let (ok, stdout, _) = medusa(&["freq", "--design", "medusa", "--ports", "32"]);
    assert!(ok);
    assert!(stdout.contains("MHz peak"), "{stdout}");
    // The 1024-bit baseline point fails timing (Fig 6).
    let (ok, stdout, _) =
        medusa(&["freq", "--design", "baseline", "--ports", "64", "--w-line", "1024", "--dpus", "96"]);
    assert!(ok);
    assert!(stdout.contains("FAILS timing"), "{stdout}");
}

#[test]
fn bad_geometry_rejected() {
    let (ok, _, stderr) = medusa(&["resources", "--ports", "999"]);
    assert!(!ok);
    assert!(stderr.contains("error"), "{stderr}");
}

#[test]
fn info_reports_environment() {
    let (ok, stdout, _) = medusa(&["info"]);
    assert!(ok);
    assert!(stdout.contains("device model"));
    assert!(stdout.contains("PJRT"));
}

#[test]
fn run_builtin_scenario_verifies() {
    let (ok, stdout, stderr) = medusa(&["run", "--scenario", "multi-tenant-mix"]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("resnet-tiny"));
    assert!(stdout.contains("mobilenet-tiny"));
    assert!(stdout.contains("all tenants verified"));
}

#[test]
fn run_scenario_file_capture_and_replay_roundtrip() {
    let dir = std::env::temp_dir().join(format!("medusa_cli_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("mix.trace");
    let trace_s = trace.to_str().unwrap();
    let (ok, stdout, stderr) = medusa(&[
        "run",
        "--scenario",
        "configs/scenarios/multi_tenant_mix.toml",
        "--capture",
        trace_s,
    ]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(trace.exists(), "capture must write the trace file");
    let (ok, stdout, stderr) = medusa(&["replay", trace_s]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("exact + timing expectations reproduced"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_unknown_scenario_fails() {
    let (ok, _, stderr) = medusa(&["run", "--scenario", "no-such-scenario.toml"]);
    assert!(!ok);
    assert!(stderr.contains("error"), "{stderr}");
}

#[test]
fn replay_golden_trace_checks_movement_counters() {
    let (ok, stdout, stderr) = medusa(&["replay", "golden/micro_medusa.trace"]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("reproduced"), "{stdout}");
}

#[test]
fn resources_accepts_hybrid_specs_and_validates_them() {
    let (ok, stdout, _) = medusa(&["resources", "--design", "hybrid:r8", "--ports", "32"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("hybrid"));
    // Radix above W_line/W_acc is rejected with a clean error.
    let (ok, _, stderr) =
        medusa(&["resources", "--design", "hybrid:r64", "--w-line", "128", "--ports", "8"]);
    assert!(!ok);
    assert!(stderr.contains("error"), "{stderr}");
}

#[test]
fn run_scenario_on_hybrid_design_verifies() {
    let (ok, stdout, stderr) =
        medusa(&["run", "--scenario", "multi-tenant-mix", "--design", "hybrid:r4"]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("all tenants verified"));
}

#[test]
fn serve_smoke_reports_latency_and_verifies() {
    let (ok, stdout, stderr) = medusa(&["serve", "--smoke"]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("latency p50"), "{stdout}");
    assert!(stdout.contains("goodput"), "{stdout}");
    assert!(stdout.contains("all tenants verified"), "{stdout}");
}

#[test]
fn serve_json_report_carries_slo_and_tail_latency() {
    let dir = std::env::temp_dir().join(format!("medusa_cli_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let json = dir.join("serve.json");
    let json_s = json.to_str().unwrap();
    let (ok, stdout, stderr) = medusa(&["serve", "--smoke", "--json", json_s]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    let text = std::fs::read_to_string(&json).unwrap();
    for key in ["\"p50_cycles\"", "\"p99_cycles\"", "\"slo_met\"", "\"goodput_rps\"", "\"fingerprint\""] {
        assert!(text.contains(key), "serve JSON missing {key}:\n{text}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_scenario_file_with_serving_section_runs() {
    let (ok, stdout, stderr) =
        medusa(&["serve", "--scenario", "configs/scenarios/serving_poisson.toml"]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("latency p50"), "{stdout}");
}

#[test]
fn serve_rejects_scenarios_without_a_serving_section() {
    let (ok, _, stderr) = medusa(&["serve", "--scenario", "single-tiny-vgg"]);
    assert!(!ok);
    assert!(stderr.contains("no [serving] section"), "{stderr}");
}

#[test]
fn explore_smoke_emits_frontier() {
    let (ok, stdout, stderr) = medusa(&["explore", "--smoke", "--no-cache"]);
    assert!(ok, "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("Pareto frontier"), "{stdout}");
    assert!(stdout.contains("frontier size"), "{stdout}");
    // The evaluated table carries at least one hybrid family member.
    assert!(stdout.contains("hybrid:r4"), "{stdout}");
}
