//! Runtime integration: the AOT-compiled JAX/Pallas artifacts, loaded and
//! executed from Rust via PJRT, must agree bit-for-bit with the Q8.8
//! golden model. Requires `make artifacts` and the `pjrt` feature (the
//! `xla` crate is not in the offline registry, so this whole suite is
//! compiled out by default).
#![cfg(feature = "pjrt")]

use medusa::accel::dnn::ConvLayer;
use medusa::accel::golden::conv2d_q88;
use medusa::accel::quant::Fixed16;
use medusa::runtime::{Artifacts, ConvExecutor, RuntimeClient};
use medusa::util::Prng;

fn executor_or_skip() -> Option<ConvExecutor> {
    match ConvExecutor::new() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP (run `make artifacts` first): {err}");
            None
        }
    }
}

fn rand_tensors(prng: &mut Prng, l: &ConvLayer) -> (Vec<Fixed16>, Vec<Fixed16>, Vec<Fixed16>) {
    let ifmap = (0..l.ifmap_words()).map(|_| Fixed16((prng.next_u64() & 0xfff) as i16 - 2048)).collect();
    let weights = (0..l.out_c * l.in_c * l.k * l.k)
        .map(|_| Fixed16((prng.next_u64() & 0xff) as i16 - 128))
        .collect();
    let bias = (0..l.out_c).map(|_| Fixed16((prng.next_u64() & 0xff) as i16 - 128)).collect();
    (ifmap, weights, bias)
}

#[test]
fn artifacts_manifest_complete() {
    let Some(exec) = executor_or_skip() else { return };
    let names = exec.artifact_names();
    for expect in ["conv1", "conv2", "down1", "conv3", "down2", "conv4", "quickstart", "medusa_transpose"] {
        assert!(names.contains(&expect), "missing artifact {expect}; have {names:?}");
    }
}

#[test]
fn quickstart_artifact_matches_golden() {
    let Some(mut exec) = executor_or_skip() else { return };
    let layer = exec.layer_of("quickstart").unwrap();
    let mut prng = Prng::new(99);
    let (ifmap, weights, bias) = rand_tensors(&mut prng, &layer);
    let got = exec.run_conv("quickstart", &ifmap, &weights, &bias).unwrap();
    let want = conv2d_q88(&layer, &ifmap, &weights, &bias);
    assert_eq!(got, want, "PJRT artifact must be bit-identical to the golden model");
}

#[test]
fn all_tiny_vgg_layers_match_golden() {
    let Some(mut exec) = executor_or_skip() else { return };
    let mut prng = Prng::new(7);
    for name in ["conv1", "conv2", "down1", "conv3", "down2", "conv4"] {
        let layer = exec.layer_of(name).unwrap();
        let (ifmap, weights, bias) = rand_tensors(&mut prng, &layer);
        let got = exec.run_conv(name, &ifmap, &weights, &bias).unwrap();
        let want = conv2d_q88(&layer, &ifmap, &weights, &bias);
        assert_eq!(got, want, "layer {name}");
    }
}

#[test]
fn executor_rejects_wrong_shapes() {
    let Some(mut exec) = executor_or_skip() else { return };
    let layer = exec.layer_of("quickstart").unwrap();
    let bad_ifmap = vec![Fixed16::ZERO; layer.ifmap_words() + 1];
    let weights = vec![Fixed16::ZERO; layer.out_c * layer.in_c * layer.k * layer.k];
    let bias = vec![Fixed16::ZERO; layer.out_c];
    assert!(exec.run_conv("quickstart", &bad_ifmap, &weights, &bias).is_err());
}

#[test]
fn transpose_artifact_runs_and_transposes() {
    let Some(_) = executor_or_skip() else { return };
    let artifacts = Artifacts::discover().unwrap();
    let entry = artifacts.get("medusa_transpose").unwrap();
    let n = entry.in_c; // manifest packs N in the in_c field
    let mut client = RuntimeClient::cpu().unwrap();
    client.load_hlo_text("medusa_transpose", &entry.path).unwrap();
    // Bank-major input tile: entry [y, x] = word y of port x's line; the
    // kernel must emit the port-major tile (row x = port x's line).
    let lines: Vec<Vec<f64>> =
        (0..n).map(|x| (0..n).map(|y| (x * n + y) as f64).collect()).collect();
    let mut bank_major = vec![0f64; n * n];
    for x in 0..n {
        for y in 0..n {
            bank_major[y * n + x] = lines[x][y];
        }
    }
    let input = xla::Literal::vec1(&bank_major).reshape(&[n as i64, n as i64]).unwrap();
    let out = client.execute("medusa_transpose", &[input]).unwrap();
    let flat: Vec<f64> = out[0].to_vec().unwrap();
    for x in 0..n {
        for y in 0..n {
            assert_eq!(flat[x * n + y], lines[x][y], "port {x} word {y}");
        }
    }
}

#[test]
fn repeated_execution_is_deterministic() {
    let Some(mut exec) = executor_or_skip() else { return };
    let layer = exec.layer_of("quickstart").unwrap();
    let mut prng = Prng::new(1234);
    let (ifmap, weights, bias) = rand_tensors(&mut prng, &layer);
    let a = exec.run_conv("quickstart", &ifmap, &weights, &bias).unwrap();
    let b = exec.run_conv("quickstart", &ifmap, &weights, &bias).unwrap();
    assert_eq!(a, b);
}
