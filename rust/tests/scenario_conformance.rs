//! Conformance suite for the workload scenario engine and the
//! deterministic trace capture/replay harness (PR 3).
//!
//! What it locks down:
//!
//! * every zoo network runs end to end on both interconnect designs,
//!   golden-verified, and both designs deliver identical data;
//! * capture -> replay reproduces every counter, cycle count, and
//!   per-port wait exactly (the trace really is canonical);
//! * the checked-in golden traces replay to their recorded stats
//!   (`MEDUSA_REGEN_GOLDEN=1` rewrites them with full timing);
//! * a scenario matrix sweep is bit-identical sequential vs parallel;
//! * scenario TOML files on disk stay loadable and match the built-ins.

use medusa::config::SystemConfig;
use medusa::interconnect::Design;
use medusa::sim::trace::ScenarioTrace;
use medusa::types::Geometry;
use medusa::workload::scenario::TenantSpec;
use medusa::workload::{self, zoo, Scenario};

/// A small fast geometry for per-network conformance runs.
fn conformance_cfg(design: Design) -> SystemConfig {
    SystemConfig {
        design,
        geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
        dotprod_units: 16,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: Some(200.0),
        ddr3_timing: false,
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 7,
        sim: Default::default(),
    }
}

#[test]
fn every_zoo_network_runs_on_both_designs_with_identical_data() {
    for net in zoo::all() {
        let mut delivered = Vec::new();
        for design in [Design::Baseline, Design::Medusa] {
            let sc = Scenario::single(
                &format!("conf-{}", net.name),
                conformance_cfg(design),
                net.clone(),
            );
            let out = workload::run_scenario(&sc)
                .unwrap_or_else(|e| panic!("{} on {:?}: {e:#}", net.name, design));
            assert!(out.all_verified(), "{} on {design:?} failed golden verification", net.name);
            assert_eq!(out.tenants.len(), 1);
            let t = &out.tenants[0];
            assert_eq!(t.report.layers.len(), net.nodes.len(), "{}", net.name);
            assert!(t.final_fm.len() == net.output_words(), "{}", net.name);
            // What the fabric ACTUALLY wrote to DRAM (not the golden).
            assert!(!t.final_dram.is_empty(), "{}", net.name);
            delivered.push(t.final_dram.clone());
        }
        // §III-F: the interconnect is data-transparent — same network,
        // same seed, identical DRAM-delivered output on both designs.
        assert_eq!(
            delivered[0], delivered[1],
            "{}: designs delivered different data to DRAM",
            net.name
        );
    }
}

#[test]
fn multi_tenant_and_staggered_scenarios_verify() {
    for name in ["multi-tenant-mix", "staggered-gemm"] {
        for design in [Design::Baseline, Design::Medusa] {
            let mut sc = Scenario::builtin(name).unwrap();
            sc.cfg.design = design;
            let out = workload::run_scenario(&sc)
                .unwrap_or_else(|e| panic!("{name} on {design:?}: {e:#}"));
            assert!(out.all_verified(), "{name} on {design:?}");
            assert_eq!(out.tenants.len(), 2);
            for t in &out.tenants {
                assert!(t.report.total_lines_moved() > 0);
            }
        }
    }
}

#[test]
fn staggered_tenant_starts_late() {
    let sc = Scenario::builtin("staggered-gemm").unwrap();
    let offset = sc.tenants[1].start_cycle;
    assert_eq!(offset, 1500);
    let out = workload::run_scenario(&sc).unwrap();
    // Tenant 1 may only be *active* (load/compute/drain counting) after
    // its start cycle, so its busy cycles must fit in [offset, end] —
    // if WaitStart were ignored, its ~full-run activity would overflow
    // this window (its idle gaps are far smaller than the offset).
    let busy: u64 = out.tenants[1].report.total_cycles();
    assert!(
        busy + offset <= out.fabric_cycles,
        "tenant 1 was active for {busy} cycles in a {}-cycle run with a {offset}-cycle stagger",
        out.fabric_cycles
    );
    // Teeth check: on an unstaggered twin the same bound must FAIL for
    // tenant 1 (its activity spans nearly the whole run, and its idle
    // gaps are far smaller than the offset) — so the assertion above
    // really does distinguish honored from ignored start cycles.
    let mut flat = sc.clone();
    flat.tenants[1].start_cycle = 0;
    let flat_out = workload::run_scenario(&flat).unwrap();
    let flat_busy = flat_out.tenants[1].report.total_cycles();
    assert!(
        flat_busy + offset > flat_out.fabric_cycles,
        "sanity: bound has no teeth (busy {flat_busy}, run {})",
        flat_out.fabric_cycles
    );
}

#[test]
fn capture_replay_reproduces_stats_exactly() {
    for name in ["single-tiny-vgg", "multi-tenant-mix"] {
        for design in [Design::Baseline, Design::Medusa] {
            let mut sc = Scenario::builtin(name).unwrap();
            sc.cfg.design = design;
            let (out, trace) = workload::run_scenario_captured(&sc)
                .unwrap_or_else(|e| panic!("{name} on {design:?}: {e:#}"));
            assert!(out.all_verified());
            assert!(trace.expect.timing_recorded);
            trace.validate().unwrap();
            // The trace must survive serialization.
            let text = trace.to_text();
            let parsed = ScenarioTrace::from_str(&text).unwrap();
            assert_eq!(parsed, trace, "{name}: trace text round-trip");
            // Replay from the parsed trace and check EVERYTHING:
            // exact counters, timing counters, cycles, per-port waits.
            let replayed = workload::verify_replay(&parsed)
                .unwrap_or_else(|e| panic!("{name} on {design:?} replay: {e:#}"));
            assert_eq!(replayed.fabric_cycles, out.fabric_cycles);
            assert_eq!(replayed.now_ps, out.now_ps);
        }
    }
}

#[test]
fn replay_detects_tampered_expectations() {
    let sc = Scenario::golden_micro(Design::Medusa);
    let (_, mut trace) = workload::run_scenario_captured(&sc).unwrap();
    // Corrupt one movement counter: verification must fail loudly.
    let slot = trace
        .expect
        .exact
        .iter_mut()
        .find(|(k, _)| k == "lp.words_loaded")
        .expect("movement counter present");
    slot.1 += 1;
    let err = workload::verify_replay(&trace).unwrap_err();
    assert!(format!("{err:#}").contains("lp.words_loaded"));
}

fn golden_path(name: &str) -> std::path::PathBuf {
    // Tests run with cwd = crate root (rust/); tolerate repo root too.
    for base in ["golden", "rust/golden"] {
        let p = std::path::Path::new(base).join(name);
        if p.exists() {
            return p;
        }
    }
    panic!("golden trace {name} not found");
}

fn check_golden(file: &str, design: Design) {
    let path = golden_path(file);
    if std::env::var("MEDUSA_REGEN_GOLDEN").is_ok() {
        let sc = Scenario::golden_micro(design);
        let (_, trace) = workload::run_scenario_captured(&sc).unwrap();
        trace.save(&path).unwrap();
        eprintln!("regenerated {} with full timing", path.display());
    }
    let trace = ScenarioTrace::from_file(&path).unwrap();
    trace.validate().unwrap();
    // 1. The checked-in schedule must be exactly what capturing the
    //    micro scenario produces today (schedule regression lock).
    let sc = Scenario::golden_micro(design);
    let (out, captured) = workload::run_scenario_captured(&sc).unwrap();
    assert!(out.all_verified());
    assert_eq!(captured.steps, trace.steps, "{file}: captured schedule drifted from golden");
    assert_eq!(captured.header.tenants, trace.header.tenants, "{file}: tenant groups drifted");
    // The golden carries the COMPLETE movement-counter set (including
    // the design-specific transpose/converter counters and the other
    // design's zeros), so compare the whole exact block, not a subset.
    assert_eq!(
        captured.expect.exact, trace.expect.exact,
        "{file}: movement counters drifted from golden"
    );
    // 2. Replaying the golden must reproduce its recorded stat counters
    //    (cycles/bytes/waits too, once timing is recorded).
    let replayed = workload::verify_replay(&trace).unwrap();
    // 3. And the replayed movement counters must equal the live run's.
    for (name, want) in &trace.expect.exact {
        assert_eq!(
            out.stats.get(name),
            *want,
            "{file}: live run diverged from golden on {name}"
        );
    }
    assert_eq!(replayed.fabric_cycles, out.fabric_cycles, "{file}: replay cycle drift");
}

#[test]
fn golden_trace_micro_medusa_replays() {
    check_golden("micro_medusa.trace", Design::Medusa);
}

#[test]
fn golden_trace_micro_baseline_replays() {
    check_golden("micro_baseline.trace", Design::Baseline);
}

#[test]
fn scenario_matrix_is_bit_identical_sequential_vs_parallel() {
    // The MEDUSA_THREADS contract, without racing on the env var:
    // explicit worker counts, full-outcome fingerprints.
    let seq = medusa::run::RunOptions::new().threads(1).sweep().unwrap();
    let par = medusa::run::RunOptions::new().threads(4).sweep().unwrap();
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(par.iter()) {
        assert_eq!(a.scenario, b.scenario);
        assert_eq!(a.design, b.design);
        assert_eq!(a.fabric_cycles, b.fabric_cycles, "{} {:?}", a.scenario, a.design);
        assert_eq!(a.fingerprint, b.fingerprint, "{} {:?}", a.scenario, a.design);
        assert!(a.verified && b.verified);
    }
}

#[test]
fn scenario_runs_are_bit_identical_across_repeats() {
    // Same scenario, fresh systems: fingerprints must match exactly
    // (the determinism the trace substrate stands on).
    let sc = Scenario::builtin("multi-tenant-mix").unwrap();
    let a = workload::run_scenario(&sc).unwrap();
    let b = workload::run_scenario(&sc).unwrap();
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.fabric_cycles, b.fabric_cycles);
}

fn scenario_file(name: &str) -> std::path::PathBuf {
    for base in ["configs/scenarios", "rust/configs/scenarios"] {
        let p = std::path::Path::new(base).join(name);
        if p.exists() {
            return p;
        }
    }
    panic!("scenario config {name} not found");
}

#[test]
fn shipped_scenario_configs_load_and_match_builtins() {
    for (file, builtin) in [
        ("single_tiny_vgg.toml", "single-tiny-vgg"),
        ("multi_tenant_mix.toml", "multi-tenant-mix"),
        ("staggered_gemm.toml", "staggered-gemm"),
    ] {
        let sc = Scenario::from_file(scenario_file(file)).unwrap();
        assert_eq!(sc.name, builtin, "{file}");
        let b = Scenario::builtin(builtin).unwrap();
        assert_eq!(sc.tenants.len(), b.tenants.len(), "{file}");
        for (ft, bt) in sc.tenants.iter().zip(b.tenants.iter()) {
            assert_eq!(ft.net.name, bt.net.name, "{file}");
            assert_eq!(ft.start_cycle, bt.start_cycle, "{file}");
            assert_eq!(ft.seed, bt.seed, "{file}");
        }
        assert_eq!(sc.cfg.geometry, b.cfg.geometry, "{file}");
        assert_eq!(sc.cfg.dotprod_units, b.cfg.dotprod_units, "{file}");
        // A shipped file must actually run.
        let out = workload::run_scenario(&sc).unwrap();
        assert!(out.all_verified(), "{file}");
    }
}

#[test]
fn port_group_isolation_matches_solo_runs() {
    // A tenant sharing the fabric must still move exactly its own data:
    // run gemm-mlp alone on 4 of 8 ports, then alongside a neighbour,
    // and compare its delivered feature map.
    let cfg = conformance_cfg(Design::Medusa);
    let solo = {
        let sc = Scenario {
            name: "solo-half".into(),
            cfg: cfg.clone(),
            tenants: vec![TenantSpec {
                net: zoo::gemm_mlp(),
                read_ports: 4,
                write_ports: 4,
                start_cycle: 0,
                seed: 42,
            }],
        };
        workload::run_scenario(&sc).unwrap()
    };
    let shared = {
        let sc = Scenario {
            name: "shared-half".into(),
            cfg,
            tenants: vec![
                TenantSpec {
                    net: zoo::gemm_mlp(),
                    read_ports: 4,
                    write_ports: 4,
                    start_cycle: 0,
                    seed: 42,
                },
                TenantSpec {
                    net: zoo::mobilenet_tiny(),
                    read_ports: 4,
                    write_ports: 4,
                    start_cycle: 0,
                    seed: 43,
                },
            ],
        };
        workload::run_scenario(&sc).unwrap()
    };
    assert!(solo.all_verified() && shared.all_verified());
    // Compare what actually landed in DRAM, not the (trivially equal)
    // precomputed golden vectors.
    assert!(!solo.tenants[0].final_dram.is_empty());
    assert_eq!(
        solo.tenants[0].final_dram, shared.tenants[0].final_dram,
        "fabric sharing must not change the data a tenant delivers"
    );
    // Contention can only slow the shared run down, never speed it up.
    assert!(shared.fabric_cycles >= solo.fabric_cycles);
}
