//! Conformance suite for the inference-serving layer (PR 7): open-loop
//! arrivals, dynamic batching, and SLO accounting must be **bit-exact**
//! replicas of themselves under every execution strategy.
//!
//! What it locks down, per ISSUE 7's acceptance criteria:
//!
//! * a seeded serving scenario reports p50/p99 latency, queue depth,
//!   and goodput as first-class sampled series, bit-identical across
//!   all four backend combinations (full/elided x stepwise/leap) and
//!   across sequential vs parallel matrix execution;
//! * idle-edge leaping jumps straight through sparse inter-arrival gaps
//!   without moving a single latency sample;
//! * serving composes with the PR 6 standard fault campaign (faults
//!   stall and tag traffic, arrivals keep flowing, results stay
//!   backend-invariant);
//! * captured serving traces record the spec in their header and replay
//!   bit-exactly under every backend;
//! * serving-free traces (the checked-in goldens) carry no `serving.*`
//!   keys at all — the format is byte-identical to pre-serving builds.

use medusa::config::{EdgeMode, PayloadMode, SimBackend};
use medusa::run::RunOptions;
use medusa::serving::ServingSpec;
use medusa::sim::stats::{Counter, SampleId};
use medusa::sim::trace::ScenarioTrace;
use medusa::workload::{self, Scenario, ScenarioOutcome};

fn backends() -> [SimBackend; 4] {
    [
        SimBackend::full(),
        SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
        SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
        SimBackend::fast(),
    ]
}

/// Everything the serving layer observes: the aggregate report (per
/// tenant) and the serving counter/sample surface.
fn assert_serving_exact(a: &ScenarioOutcome, b: &ScenarioOutcome, what: &str) {
    assert_eq!(a.fabric_cycles, b.fabric_cycles, "{what}: fabric_cycles");
    assert_eq!(a.now_ps, b.now_ps, "{what}: now_ps");
    let (ra, rb) = (a.serving.as_ref().unwrap(), b.serving.as_ref().unwrap());
    assert_eq!(ra.tenants.len(), rb.tenants.len(), "{what}: tenant count");
    for (t, (ta, tb)) in ra.tenants.iter().zip(rb.tenants.iter()).enumerate() {
        assert_eq!(ta, tb, "{what}: tenant {t} serving report");
    }
    for id in [
        Counter::ServingBatches,
        Counter::ServingRequestsArrived,
        Counter::ServingRequestsCompleted,
        Counter::ServingSloMet,
    ] {
        assert_eq!(a.stats.count(id), b.stats.count(id), "{what}: counter {}", id.name());
    }
    for id in
        [SampleId::ServingBatchOccupancy, SampleId::ServingLatencyCycles, SampleId::ServingQueueDepth]
    {
        let (sa, sb) = (a.stats.series_of(id), b.stats.series_of(id));
        assert_eq!(
            (sa.min, sa.max, sa.sum, sa.count),
            (sb.min, sb.max, sb.sum, sb.count),
            "{what}: series {}",
            id.name()
        );
    }
}

#[test]
fn seeded_serving_run_is_bit_identical_across_all_backends() {
    let reference = {
        let sc = Scenario::builtin("serving-poisson").unwrap();
        RunOptions::new().backend(SimBackend::full()).run(&sc).unwrap()
    };
    let rep = reference.serving.as_ref().expect("serving report");
    let t0 = &rep.tenants[0];
    assert_eq!(t0.arrived, 6, "serving-poisson serves 6 requests");
    assert_eq!(t0.completed, 6, "every request must complete");
    assert!(t0.p50_cycles > 0 && t0.p99_cycles >= t0.p50_cycles && t0.max_cycles >= t0.p99_cycles);
    assert!(t0.goodput_rps(reference.now_ps) > 0.0);
    assert!(t0.batches >= 3, "max_batch=2 over 6 requests needs at least 3 batches");
    // Queue depth / latency / occupancy are first-class sampled series.
    assert!(reference.stats.series("serving.latency_cycles").unwrap().count > 0);
    assert!(reference.stats.series("serving.queue_depth").unwrap().count > 0);
    assert!(reference.stats.series("serving.batch_occupancy").unwrap().count > 0);
    for backend in backends() {
        let sc = Scenario::builtin("serving-poisson").unwrap();
        let out = RunOptions::new().backend(backend).run(&sc).unwrap();
        assert_serving_exact(&reference, &out, &format!("{backend:?}"));
        // Full-payload variants must agree on the complete fingerprint
        // (feature maps included), not just the serving surface.
        if backend.payload == PayloadMode::Full {
            assert_eq!(reference.fingerprint(), out.fingerprint(), "{backend:?}: fingerprint");
        }
    }
}

#[test]
fn serving_matrix_rows_are_bit_identical_sequential_vs_parallel() {
    let seq = RunOptions::new().threads(1).sweep().unwrap();
    let par = RunOptions::new().threads(4).sweep().unwrap();
    let rows =
        |pts: &[medusa::eval::scenarios::ScenarioPoint]| -> Vec<(medusa::interconnect::Design, u64)> {
            pts.iter()
                .filter(|p| p.scenario == "serving-poisson")
                .map(|p| (p.design, p.fingerprint))
                .collect()
        };
    let (s, p) = (rows(&seq), rows(&par));
    assert_eq!(s.len(), 2, "serving-poisson must appear on both designs in the matrix");
    assert_eq!(s, p, "serving matrix rows diverged between worker counts");
}

#[test]
fn leap_jumps_sparse_inter_arrival_gaps_without_moving_a_sample() {
    // Three arrivals separated by huge idle gaps: the leap backend must
    // skip the gaps in O(1) and still land every admission, dispatch,
    // and completion on the same edge as the stepwise reference.
    let mut sc = Scenario::builtin("serving-poisson").unwrap();
    sc.serving = ServingSpec {
        seed: 1,
        max_batch: 1,
        max_wait: 1_000,
        arrivals: vec![500, 400_000, 800_000],
        ..ServingSpec::default()
    };
    let stepwise = RunOptions::new().backend(SimBackend::full()).run(&sc).unwrap();
    let leap = RunOptions::new()
        .backend(SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap })
        .run(&sc)
        .unwrap();
    assert!(
        stepwise.fabric_cycles > 800_000,
        "run must actually reach the last sparse arrival (got {})",
        stepwise.fabric_cycles
    );
    assert_serving_exact(&stepwise, &leap, "sparse-gap leap");
    assert_eq!(stepwise.fingerprint(), leap.fingerprint(), "sparse-gap leap fingerprint");
    let rep = leap.serving.as_ref().unwrap();
    assert_eq!(rep.tenants[0].completed, 3);
}

#[test]
fn serving_composes_with_the_standard_fault_campaign() {
    // PR 6's standard campaign: refresh stalls, CDC backpressure, LP
    // slowdown, corrupt tagging. Arrivals keep flowing through all of
    // it, and the whole composition stays backend-invariant.
    let mut sc = Scenario::builtin("serving-poisson").unwrap();
    sc.faults = medusa::fault::FaultSpec::parse_cli(
        "dram_refresh=64/8,cdc=96/6,slow=128/12,corrupt=7,seed=3",
    )
    .unwrap();
    let full = RunOptions::new().backend(SimBackend::full()).run(&sc).unwrap();
    let fast = RunOptions::new().backend(SimBackend::fast()).run(&sc).unwrap();
    assert_serving_exact(&full, &fast, "serving under faults");
    assert!(full.all_verified(), "delay + detect-only faults must still verify");
    let injected: u64 = [
        "fault.dram_refresh_stall_cycles",
        "fault.cdc_stall_cycles",
        "fault.lp_slowdown_cycles",
        "fault.corrupt_injected",
    ]
    .iter()
    .map(|n| full.stats.get(n))
    .sum();
    assert!(injected > 0, "standard campaign injected nothing");
    assert_eq!(full.serving.as_ref().unwrap().tenants[0].completed, 6);
}

#[test]
fn wedged_tenant_reports_starved_with_defined_zero_percentiles() {
    // The empty-series audit: a tenant admitted (its arrival schedule
    // materialized) but wedged before any batch completes must yield a
    // defined p50/p99 of 0 plus the starved flag — never a panic or a
    // bogus percentile index. The degrade policy quiesces the wedged
    // tenant and ends the run cleanly.
    let mut sc = Scenario::builtin("serving-poisson").unwrap();
    sc.faults =
        medusa::fault::FaultSpec::parse_cli("wedge=0@64,watchdog=512,policy=degrade,seed=11")
            .unwrap();
    let full = RunOptions::new().backend(SimBackend::full()).run(&sc).unwrap();
    let rep = full.serving.as_ref().expect("serving report must exist for a starved tenant");
    let t0 = &rep.tenants[0];
    assert_eq!(t0.arrived, 6, "arrivals are materialized up front, wedge or not");
    assert_eq!(t0.completed, 0, "wedged at cycle 64: nothing may complete");
    assert!(t0.starved, "zero completions out of {} arrivals must set starved", t0.arrived);
    assert_eq!(
        (t0.p50_cycles, t0.p99_cycles, t0.max_cycles, t0.slo_met as u64),
        (0, 0, 0, 0),
        "empty latency series must summarize to defined zeros"
    );
    assert_eq!(t0.goodput_rps(full.now_ps), 0.0);
    assert_eq!(rep.worst_p99(), 0);
    assert!(!full.all_verified(), "the degraded tenant cannot verify");
    // And the whole composition stays backend-invariant.
    let fast = RunOptions::new().backend(SimBackend::fast()).run(&sc).unwrap();
    assert_serving_exact(&full, &fast, "starved tenant under fast backend");
    // The healthy baseline run does NOT carry the flag.
    let healthy = RunOptions::new()
        .backend(SimBackend::full())
        .run(&Scenario::builtin("serving-poisson").unwrap())
        .unwrap();
    assert!(!healthy.serving.as_ref().unwrap().tenants[0].starved);
}

#[test]
fn captured_serving_trace_records_spec_and_replays_under_every_backend() {
    let sc = Scenario::builtin("serving-poisson").unwrap();
    let (out, trace) = workload::run_scenario_captured(&sc).unwrap();
    assert_eq!(trace.header.serving, sc.serving, "header must record the serving spec");
    let text = trace.to_text();
    assert!(text.contains("serving.requests = 6"), "spec missing from trace text:\n{text}");
    let parsed = ScenarioTrace::from_str(&text).unwrap();
    assert_eq!(parsed, trace, "serving trace text round-trip");
    for backend in backends() {
        let replayed = RunOptions::new()
            .backend(backend)
            .verify_replay(&parsed)
            .unwrap_or_else(|e| panic!("serving replay under {backend:?}: {e:#}"));
        assert_serving_exact(&out, &replayed, &format!("replay {backend:?}"));
    }
}

#[test]
fn serving_free_goldens_carry_no_serving_keys() {
    // The regression half of the format contract: pre-serving traces
    // are untouched, byte for byte — so they must contain no serving
    // namespace at all, and still replay cleanly (their expect blocks
    // were captured before the serving layer existed).
    for file in ["micro_baseline.trace", "micro_medusa.trace", "micro_medusa_faulted.trace"] {
        let path = ["golden", "rust/golden"]
            .iter()
            .map(|b| std::path::Path::new(b).join(file))
            .find(|p| p.exists())
            .unwrap_or_else(|| panic!("golden trace {file} not found"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("serving."), "{file} must carry no serving keys");
        let trace = ScenarioTrace::from_str(&text).unwrap();
        assert!(trace.header.serving.is_none());
        RunOptions::new()
            .verify_replay(&trace)
            .unwrap_or_else(|e| panic!("{file} replay: {e:#}"));
    }
}
