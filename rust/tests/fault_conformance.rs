//! Conformance suite for deterministic fault injection (PR 6): a
//! seeded fault campaign is part of the simulated machine, so every
//! determinism contract the clean simulator honours must survive with
//! faults armed.
//!
//! What it locks down, per ISSUE 6's acceptance criteria:
//!
//! * the standard stall+corrupt campaign on every zoo scenario × all
//!   three design families is **bit-identical** across all four backend
//!   combinations (seq runs are covered by `scenario_conformance`'s
//!   fingerprint tests; here the axes are elided-vs-full and
//!   leap-vs-stepwise);
//! * delay faults and detect-only corruption leave the movement
//!   counters and golden-model verification untouched — a faulted run
//!   still verifies, it just takes longer;
//! * a wedged tenant terminates with a typed
//!   `SimError::TenantStalled` (not a hang, not a panic), at the SAME
//!   fabric cycle under stepwise and leap edge handling;
//! * the `degrade` policy quiesces the wedged tenant, drains its port
//!   group, samples recovery/goodput series, and lets the other tenant
//!   finish — again bit-identically across backends;
//! * a captured faulty trace records the campaign in its header and
//!   replays bit-exactly under every backend;
//! * the checked-in faulted golden (`micro_medusa_faulted.trace`)
//!   replays under every backend with its `[expect.exact]` block
//!   verbatim from the clean micro golden.

use medusa::config::{EdgeMode, PayloadMode, SimBackend, SystemConfig};
use medusa::fault::{FaultSpec, SimError};
use medusa::interconnect::hybrid::HybridConfig;
use medusa::interconnect::Design;
use medusa::sim::stats::{Counter, SampleId};
use medusa::sim::trace::ScenarioTrace;
use medusa::types::Geometry;
use medusa::workload::{self, zoo, Scenario, ScenarioOutcome};

/// The standard campaign: all three delay classes plus detect-only
/// corruption, same spec the faulted golden was captured under.
const CAMPAIGN: &str = "dram_refresh=64/8,cdc=96/6,slow=128/12,corrupt=7,seed=3";

/// The per-cycle/per-event injection counters (not the detect/masked
/// split, which is asserted separately to sum to `corrupt_injected`).
const FAULT_CLASSES: [&str; 4] = [
    "fault.dram_refresh_stall_cycles",
    "fault.cdc_stall_cycles",
    "fault.lp_slowdown_cycles",
    "fault.corrupt_injected",
];

/// Same geometry as the fast-backend suite: N = 8 so the hybrid family
/// member is a genuine partial transpose, irrational clock ratio so
/// fabric and memory edges interleave non-trivially around the fault
/// windows.
fn cfg(design: Design, sim: SimBackend) -> SystemConfig {
    SystemConfig {
        design,
        geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
        dotprod_units: 16,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: Some(225.0),
        ddr3_timing: true,
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 7,
        sim,
    }
}

fn families() -> [Design; 3] {
    [
        Design::Baseline,
        Design::Medusa,
        Design::Hybrid(HybridConfig { transpose_radix: 4, ..HybridConfig::default() }),
    ]
}

fn backends() -> [SimBackend; 4] {
    [
        SimBackend::full(),
        SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
        SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
        SimBackend::fast(),
    ]
}

/// Every observable the backends promise to preserve, fault counters
/// and degrade series included (they live in the ordinary counter and
/// sample registries).
fn assert_stats_exact(a: &ScenarioOutcome, b: &ScenarioOutcome, what: &str) {
    assert_eq!(a.fabric_cycles, b.fabric_cycles, "{what}: fabric_cycles");
    assert_eq!(a.mem_cycles, b.mem_cycles, "{what}: mem_cycles");
    assert_eq!(a.now_ps, b.now_ps, "{what}: now_ps");
    for &id in Counter::ALL.iter() {
        assert_eq!(a.stats.count(id), b.stats.count(id), "{what}: counter {}", id.name());
    }
    for &id in SampleId::ALL.iter() {
        let (sa, sb) = (a.stats.series_of(id), b.stats.series_of(id));
        assert_eq!(
            (sa.min, sa.max, sa.sum, sa.count),
            (sb.min, sb.max, sb.sum, sb.count),
            "{what}: series {}",
            id.name()
        );
    }
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (t, (ta, tb)) in a.tenants.iter().zip(b.tenants.iter()).enumerate() {
        assert_eq!(ta.read_waits, tb.read_waits, "{what}: tenant {t} read waits");
        assert_eq!(ta.write_waits, tb.write_waits, "{what}: tenant {t} write waits");
        assert_eq!(
            ta.report.total_cycles(),
            tb.report.total_cycles(),
            "{what}: tenant {t} busy cycles"
        );
        assert_eq!(
            ta.report.total_lines_moved(),
            tb.report.total_lines_moved(),
            "{what}: tenant {t} lines moved"
        );
    }
}

fn run_faulted(
    name: &str,
    design: Design,
    net: workload::WorkloadNet,
    sim: SimBackend,
    faults: &str,
) -> ScenarioOutcome {
    let mut sc = Scenario::single(name, cfg(design, sim), net);
    sc.faults = FaultSpec::parse_cli(faults).expect("campaign spec parses");
    workload::run_scenario(&sc)
        .unwrap_or_else(|e| panic!("{name} / {design:?} / {sim:?} / {faults}: {e:#}"))
}

#[test]
fn standard_campaign_is_bit_identical_across_backends_on_every_zoo_scenario() {
    // Accumulated per-class totals: every fault class must fire
    // somewhere in the sweep (each individual net/design pair only has
    // to inject *something*).
    let mut class_totals = [0u64; 4];
    for net in zoo::all() {
        for design in families() {
            let full =
                run_faulted(&format!("flt-{}", net.name), design, net.clone(), SimBackend::full(), CAMPAIGN);
            // Delay faults + detect-only corruption: the workload's
            // golden check must still pass on the faulted run.
            assert!(full.all_verified(), "{} on {design:?}: faulted run must verify", net.name);
            let injected: u64 = FAULT_CLASSES.iter().map(|n| full.stats.get(n)).sum();
            assert!(injected > 0, "{} on {design:?}: campaign injected nothing", net.name);
            // Every corrupt event is either detected or masked; none
            // silently disappears.
            assert_eq!(
                full.stats.get("fault.corrupt_injected"),
                full.stats.get("fault.detected") + full.stats.get("fault.masked"),
                "{} on {design:?}: corrupt events unaccounted for",
                net.name
            );
            for (slot, name) in class_totals.iter_mut().zip(FAULT_CLASSES.iter()) {
                *slot += full.stats.get(name);
            }

            let elided = run_faulted(
                &format!("flt-{}", net.name),
                design,
                net.clone(),
                SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
                CAMPAIGN,
            );
            assert_stats_exact(&full, &elided, &format!("{} {design:?} elided", net.name));

            let leap = run_faulted(
                &format!("flt-{}", net.name),
                design,
                net.clone(),
                SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
                CAMPAIGN,
            );
            // Leap preserves payload, so the FULL fingerprint must
            // match: fault windows cap or split leaps, never get
            // skipped by one.
            assert_eq!(
                full.fingerprint(),
                leap.fingerprint(),
                "{} {design:?}: leap changed the faulted outcome fingerprint",
                net.name
            );
            assert_stats_exact(&full, &leap, &format!("{} {design:?} leap", net.name));

            let fast = run_faulted(
                &format!("flt-{}", net.name),
                design,
                net.clone(),
                SimBackend::fast(),
                CAMPAIGN,
            );
            assert_stats_exact(&full, &fast, &format!("{} {design:?} fast", net.name));
        }
    }
    for (total, name) in class_totals.iter().zip(FAULT_CLASSES.iter()) {
        assert!(*total > 0, "fault class {name} never fired across the whole sweep");
    }
}

#[test]
fn captured_faulty_trace_records_campaign_and_replays_under_every_backend() {
    let mut sc =
        Scenario::single("flt-replay", cfg(Design::Medusa, SimBackend::full()), zoo::gemm_mlp());
    sc.faults = FaultSpec::parse_cli(CAMPAIGN).unwrap();
    let (out, trace) = workload::run_scenario_captured(&sc).unwrap();
    // The header must carry the campaign — replaying a faulty trace
    // without re-arming the faults could never be bit-exact.
    assert_eq!(trace.header.faults, sc.faults, "header must record the fault campaign");
    let text = trace.to_text();
    assert!(text.contains("faults.seed = 3"), "campaign missing from trace text:\n{text}");
    let parsed = ScenarioTrace::from_str(&text).unwrap();
    assert_eq!(parsed, trace, "faulty trace text round-trip");
    for backend in backends() {
        let replayed = medusa::run::RunOptions::new()
            .backend(backend)
            .verify_replay(&parsed)
            .unwrap_or_else(|e| panic!("faulty replay under {backend:?}: {e:#}"));
        assert_eq!(replayed.fabric_cycles, out.fabric_cycles, "{backend:?}: cycle drift");
        for name in FAULT_CLASSES {
            assert_eq!(
                replayed.stats.get(name),
                out.stats.get(name),
                "{backend:?}: replay drifted on {name}"
            );
        }
    }
}

#[test]
fn wedged_tenant_errors_with_tenant_stalled_at_identical_cycle_across_backends() {
    let mut fired = Vec::new();
    for backend in backends() {
        let mut sc = Scenario::single("flt-wedge", cfg(Design::Medusa, backend), zoo::gemm_mlp());
        // Wedge the only tenant mid-load; the watchdog horizon is small
        // so the run terminates quickly instead of hanging.
        sc.faults = FaultSpec::parse_cli("wedge=0@400,watchdog=512,seed=11").unwrap();
        let err = workload::run_scenario(&sc).expect_err("wedged run must error, not hang");
        let stalled = err
            .downcast_ref::<SimError>()
            .unwrap_or_else(|| panic!("{backend:?}: not a typed SimError: {err:#}"));
        let SimError::TenantStalled { tenant, cycle, state, dump } = stalled;
        assert_eq!(*tenant, 0, "{backend:?}: wrong tenant blamed");
        // The wedge lands at 400 and the horizon is 512, so the verdict
        // must arrive right after cycle 912 (small slack for where the
        // last pre-wedge tick is observed).
        assert!(
            (910..=940).contains(cycle),
            "{backend:?}: watchdog fired at {cycle}, expected just past 400 + 512"
        );
        assert!(!state.is_empty(), "{backend:?}: missing engine state");
        assert!(dump.contains("lp0"), "{backend:?}: dump must include per-LP state:\n{dump}");
        fired.push(*cycle);
    }
    // The acceptance criterion: identical elapsed cycles under every
    // backend — the wedge suppresses leaping, so leap-mode execution
    // steps through the frozen span exactly like the reference.
    assert!(
        fired.windows(2).all(|w| w[0] == w[1]),
        "TenantStalled cycles diverged across backends: {fired:?}"
    );
}

#[test]
fn degrade_policy_quiesces_wedged_tenant_and_keeps_survivors_running() {
    let mut reference: Option<ScenarioOutcome> = None;
    for backend in backends() {
        let mut sc = Scenario::builtin("multi-tenant-mix").unwrap();
        sc.cfg.sim = backend;
        // Wedge tenant 1 early (mid-load) so the degrade path also has
        // in-flight read lines to drain.
        sc.faults =
            FaultSpec::parse_cli("wedge=1@64,watchdog=512,policy=degrade,seed=11").unwrap();
        let out = workload::run_scenario(&sc)
            .unwrap_or_else(|e| panic!("degraded run must complete under {backend:?}: {e:#}"));
        assert!(!out.tenants[1].verified, "{backend:?}: wedged tenant must be unverified");
        assert!(out.tenants[0].verified, "{backend:?}: surviving tenant must verify");
        let rec = out
            .stats
            .series("degrade.recovery_cycles")
            .unwrap_or_else(|| panic!("{backend:?}: no recovery sample"));
        assert_eq!(rec.count, 1, "{backend:?}: exactly one quiesce/recovery event");
        let good = out
            .stats
            .series("degrade.goodput_lines")
            .unwrap_or_else(|| panic!("{backend:?}: no goodput sample"));
        assert_eq!(good.count, 1, "{backend:?}: one surviving tenant sampled");
        assert!(good.sum > 0, "{backend:?}: survivor moved no lines");
        match &reference {
            Some(r) => assert_stats_exact(r, &out, &format!("degrade under {backend:?}")),
            None => reference = Some(out),
        }
    }
}

fn golden_path(name: &str) -> std::path::PathBuf {
    for base in ["golden", "rust/golden"] {
        let p = std::path::Path::new(base).join(name);
        if p.exists() {
            return p;
        }
    }
    panic!("golden trace {name} not found");
}

#[test]
fn golden_faulted_trace_replays_under_every_backend() {
    let path = golden_path("micro_medusa_faulted.trace");
    if std::env::var("MEDUSA_REGEN_GOLDEN").is_ok() {
        let sc = Scenario::golden_micro_faulted(Design::Medusa);
        let (_, trace) = workload::run_scenario_captured(&sc).unwrap();
        trace.save(&path).unwrap();
        eprintln!("regenerated {} with full timing", path.display());
    }
    let trace = ScenarioTrace::from_file(&path).unwrap();
    trace.validate().unwrap();
    let sc = Scenario::golden_micro_faulted(Design::Medusa);
    assert_eq!(trace.header.faults, sc.faults, "golden campaign drifted from the builtin");
    let (out, captured) = workload::run_scenario_captured(&sc).unwrap();
    assert!(out.all_verified(), "faulted micro must still verify (delay + detect-only faults)");
    assert_eq!(captured.steps, trace.steps, "captured schedule drifted from golden");
    assert_eq!(captured.header.tenants, trace.header.tenants, "tenant groups drifted");
    assert_eq!(captured.header.faults, trace.header.faults, "recorded campaign drifted");
    // The movement counters are VERBATIM the clean micro golden's: the
    // campaign delays and corrupt-tags traffic but neither adds nor
    // drops a single line.
    assert_eq!(
        captured.expect.exact, trace.expect.exact,
        "movement counters drifted (fault injection must be movement-invariant)"
    );
    for (name, want) in &trace.expect.exact {
        assert_eq!(out.stats.get(name), *want, "live faulted run diverged from golden on {name}");
    }
    for backend in backends() {
        let replayed = medusa::run::RunOptions::new()
            .backend(backend)
            .verify_replay(&trace)
            .unwrap_or_else(|e| panic!("golden faulted replay under {backend:?}: {e:#}"));
        assert_eq!(replayed.fabric_cycles, out.fabric_cycles, "{backend:?}: cycle drift");
        let injected: u64 = FAULT_CLASSES.iter().map(|n| replayed.stats.get(n)).sum();
        assert!(injected > 0, "{backend:?}: golden campaign injected nothing");
    }
}
