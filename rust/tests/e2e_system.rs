//! End-to-end system tests: full tiny-VGG inference through the
//! cycle-accurate stack on both interconnects, with DDR3 timing, and —
//! when artifacts are present — the PJRT compute backend, verifying the
//! whole three-layer story in one place.

use medusa::accel::dnn::Network;
use medusa::accel::quant::Fixed16;
use medusa::config::SystemConfig;
use medusa::coordinator::{ComputeBackend, InferenceDriver};
use medusa::interconnect::Design;
use medusa::runtime::ConvExecutor;
use medusa::types::Geometry;
use medusa::util::Prng;

fn paper_cfg(design: Design) -> SystemConfig {
    SystemConfig {
        design,
        geometry: Geometry::paper_default(),
        dotprod_units: 64,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: None, // ask the P&R model — the honest path
        ddr3_timing: true,
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 2024,
        sim: Default::default(),
    }
}

fn test_input(net: &Network, seed: u64) -> Vec<Fixed16> {
    let mut p = Prng::new(seed);
    (0..net.layers[0].ifmap_words())
        .map(|_| Fixed16::from_f32((p.f64() as f32) * 2.0 - 1.0))
        .collect()
}

#[test]
fn tiny_vgg_golden_both_designs_identical_output() {
    let net = Network::tiny_vgg();
    let input = test_input(&net, 5);
    let mut outputs = Vec::new();
    for design in [Design::Medusa, Design::Baseline] {
        let mut drv = InferenceDriver::new(paper_cfg(design), ComputeBackend::Golden).unwrap();
        let (report, fm) = drv.run(&net, &input).unwrap();
        assert!(report.all_verified(), "{design:?}: all layers must verify");
        assert_eq!(report.layers.len(), net.layers.len());
        outputs.push((design, report, fm));
    }
    assert_eq!(outputs[0].2, outputs[1].2, "drop-in interchangeability (§III-F)");
    // Medusa's fabric clock (from the P&R model) beats the baseline's at
    // this 2048-DSP design point, so simulated wall-clock must be lower.
    let (m_t, b_t) = (outputs[0].1.total_time_ms(), outputs[1].1.total_time_ms());
    assert!(
        m_t < b_t,
        "medusa {m_t:.3}ms should beat baseline {b_t:.3}ms at the Table II point"
    );
    let speedup = b_t / m_t;
    assert!(
        speedup > 1.3,
        "system-level speedup {speedup:.2}x should reflect the Fig 6 frequency gap"
    );
}

#[test]
fn tiny_vgg_pjrt_backend_matches_golden() {
    let Ok(exec) = ConvExecutor::new() else {
        eprintln!("SKIP: artifacts unavailable (run `make artifacts`)");
        return;
    };
    let net = Network::tiny_vgg();
    let input = test_input(&net, 6);
    let mut cfg = paper_cfg(Design::Medusa);
    cfg.ddr3_timing = false; // keep the test quick; timing covered above
    let mut drv = InferenceDriver::new(cfg, ComputeBackend::Pjrt(Box::new(exec))).unwrap();
    let (report, fm_pjrt) = drv.run(&net, &input).unwrap();
    assert!(report.all_verified(), "every layer: PJRT == golden AND DRAM == computed");

    let mut golden_drv =
        InferenceDriver::new(paper_cfg(Design::Medusa), ComputeBackend::Golden).unwrap();
    let (_, fm_golden) = golden_drv.run(&net, &input).unwrap();
    assert_eq!(fm_pjrt, fm_golden, "PJRT pipeline output == golden pipeline output");
}

#[test]
fn bandwidth_utilization_reported_sanely() {
    let net = Network::tiny_vgg();
    let input = test_input(&net, 7);
    let mut drv = InferenceDriver::new(paper_cfg(Design::Medusa), ComputeBackend::Golden).unwrap();
    let (report, _) = drv.run(&net, &input).unwrap();
    let g = Geometry::paper_default();
    for l in &report.layers {
        let u = l.read_bandwidth_utilization(g.read_ports, g.words_per_line());
        assert!(u > 0.0 && u <= 1.0, "{}: utilization {u}", l.layer);
        assert!(l.lines_read > 0 && l.lines_written > 0);
    }
    assert!(report.effective_bandwidth_gbs(g.w_line) > 0.5, "effective bandwidth too low");
}

#[test]
fn rotator_pipelining_ablation_same_results() {
    // Medusa with a fully pipelined rotator (Fig 5): same data, slightly
    // more latency, (modelled) higher frequency headroom.
    let net = Network::tiny_vgg();
    let input = test_input(&net, 8);
    let mut plain_cfg = paper_cfg(Design::Medusa);
    plain_cfg.ddr3_timing = false;
    let mut piped_cfg = plain_cfg.clone();
    piped_cfg.rotator_stages = 5; // log2(32)
    let (r_plain, fm_plain) = InferenceDriver::new(plain_cfg, ComputeBackend::Golden)
        .unwrap()
        .run(&net, &input)
        .unwrap();
    let (r_piped, fm_piped) = InferenceDriver::new(piped_cfg, ComputeBackend::Golden)
        .unwrap()
        .run(&net, &input)
        .unwrap();
    assert_eq!(fm_plain, fm_piped);
    assert!(r_plain.all_verified() && r_piped.all_verified());
    // Pipelining costs at most a handful of extra cycles per layer.
    assert!(r_piped.total_cycles() >= r_plain.total_cycles());
    assert!((r_piped.total_cycles() - r_plain.total_cycles()) < 1_000);
}

#[test]
fn ddr3_timing_slower_than_ideal() {
    let net = Network::tiny_vgg();
    let input = test_input(&net, 9);
    let cycles_with = |ddr3: bool| {
        let mut cfg = paper_cfg(Design::Medusa);
        cfg.ddr3_timing = ddr3;
        let (r, _) =
            InferenceDriver::new(cfg, ComputeBackend::Golden).unwrap().run(&net, &input).unwrap();
        r.total_cycles()
    };
    let ideal = cycles_with(false);
    let ddr3 = cycles_with(true);
    assert!(ddr3 > ideal, "DDR3 timing must cost cycles: {ddr3} vs {ideal}");
}
