//! Conformance suite for the stats-exact fast simulation backend
//! (PR 5): payload elision and idle-edge leaping, alone and combined,
//! must be **bit-identical** to the full stepwise reference on every
//! observable except the payload itself.
//!
//! What it locks down, per ISSUE 5's acceptance criteria:
//!
//! * every zoo scenario × all three design families (baseline, medusa,
//!   hybrid — intermediate radix): elided-vs-full and leap-vs-stepwise
//!   runs agree on every counter, every sample series, all three cycle
//!   clocks, and all per-port wait cycles;
//! * captured traces agree structurally: identical headers, identical
//!   step schedules, identical `exact` AND `timing` expect blocks;
//! * a trace captured by the full backend replays cleanly under the
//!   fast backend (`RunOptions::verify_replay` asserts the recorded
//!   expect block, so the golden files are a cross-backend oracle);
//! * staggered multi-tenant scenarios leap without perturbing tenant
//!   start edges;
//! * the explorer smoke grid evaluates to byte-identical Pareto output
//!   (JSON and CSV) under both backends.

use medusa::config::{EdgeMode, PayloadMode, SimBackend, SystemConfig};
use medusa::eval::explore::{bench_json, full_table};
use medusa::explore::{DesignSpace, Strategy};
use medusa::interconnect::hybrid::HybridConfig;
use medusa::run::RunOptions;
use medusa::interconnect::Design;
use medusa::sim::stats::{Counter, SampleId};
use medusa::types::Geometry;
use medusa::workload::{self, zoo, Scenario, ScenarioOutcome};

/// N = 8 geometry: radix 4 is a genuine partial transpose, so the
/// hybrid family member below exercises the third datapath, not an
/// endpoint alias of the other two.
fn cfg(design: Design, sim: SimBackend) -> SystemConfig {
    SystemConfig {
        design,
        geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
        dotprod_units: 16,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: Some(225.0), // irrational vs mem: edges interleave non-trivially
        ddr3_timing: true,             // exercise row/bank timing under elision too
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 7,
        sim,
    }
}

fn families() -> [Design; 3] {
    [
        Design::Baseline,
        Design::Medusa,
        Design::Hybrid(HybridConfig { transpose_radix: 4, ..HybridConfig::default() }),
    ]
}

/// Every observable the fast backend promises to preserve. NOT the
/// outcome fingerprint: that mixes the final feature map, which elided
/// runs intentionally don't carry.
fn assert_stats_exact(a: &ScenarioOutcome, b: &ScenarioOutcome, what: &str) {
    assert_eq!(a.fabric_cycles, b.fabric_cycles, "{what}: fabric_cycles");
    assert_eq!(a.mem_cycles, b.mem_cycles, "{what}: mem_cycles");
    assert_eq!(a.now_ps, b.now_ps, "{what}: now_ps");
    for &id in Counter::ALL.iter() {
        assert_eq!(
            a.stats.count(id),
            b.stats.count(id),
            "{what}: counter {}",
            id.name()
        );
    }
    for &id in SampleId::ALL.iter() {
        let (sa, sb) = (a.stats.series_of(id), b.stats.series_of(id));
        assert_eq!(
            (sa.min, sa.max, sa.sum, sa.count),
            (sb.min, sb.max, sb.sum, sb.count),
            "{what}: series {}",
            id.name()
        );
    }
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (t, (ta, tb)) in a.tenants.iter().zip(b.tenants.iter()).enumerate() {
        assert_eq!(ta.read_waits, tb.read_waits, "{what}: tenant {t} read waits");
        assert_eq!(ta.write_waits, tb.write_waits, "{what}: tenant {t} write waits");
        assert_eq!(
            ta.report.total_cycles(),
            tb.report.total_cycles(),
            "{what}: tenant {t} busy cycles"
        );
        assert_eq!(
            ta.report.total_lines_moved(),
            tb.report.total_lines_moved(),
            "{what}: tenant {t} lines moved"
        );
    }
}

fn run(name: &str, design: Design, net: workload::WorkloadNet, sim: SimBackend) -> ScenarioOutcome {
    let sc = Scenario::single(name, cfg(design, sim), net);
    workload::run_scenario(&sc)
        .unwrap_or_else(|e| panic!("{name} / {design:?} / {sim:?}: {e:#}"))
}

#[test]
fn every_fast_variant_matches_full_on_every_zoo_scenario_and_family() {
    // One full word-level reference per (net, design) — the expensive
    // run by design — compared against all three fast variants:
    // elision alone, leaping alone, and the combined fast backend.
    for net in zoo::all() {
        for design in families() {
            let full = run(&format!("fb-{}", net.name), design, net.clone(), SimBackend::full());
            assert!(full.all_verified(), "{} on {design:?}: full run must verify", net.name);

            let elided = run(
                &format!("fb-{}", net.name),
                design,
                net.clone(),
                SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
            );
            assert_stats_exact(&full, &elided, &format!("{} {design:?} elided", net.name));

            let leap = run(
                &format!("fb-{}", net.name),
                design,
                net.clone(),
                SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
            );
            // Leap preserves payload, so the FULL fingerprint (feature
            // maps included) must match, not just the stat surface.
            assert_eq!(
                full.fingerprint(),
                leap.fingerprint(),
                "{} {design:?}: leap changed the outcome fingerprint",
                net.name
            );
            assert!(leap.all_verified(), "{} {design:?}: leap broke golden checks", net.name);
            assert_stats_exact(&full, &leap, &format!("{} {design:?} leap", net.name));

            let fast = run(&format!("fb-{}", net.name), design, net.clone(), SimBackend::fast());
            assert_stats_exact(&full, &fast, &format!("{} {design:?} fast", net.name));
        }
    }
}

#[test]
fn captured_traces_agree_across_backends_headers_schedules_expects() {
    for design in families() {
        let full_sc = Scenario::single("fb-trace", cfg(design, SimBackend::full()), zoo::gemm_mlp());
        let fast_sc = Scenario::single("fb-trace", cfg(design, SimBackend::fast()), zoo::gemm_mlp());
        let (_, full_trace) = workload::run_scenario_captured(&full_sc).unwrap();
        let (_, fast_trace) = workload::run_scenario_captured(&fast_sc).unwrap();
        // Headers (including the resolved clock and the design spec),
        // the step schedules, and the complete expect block — exact
        // movement counters AND timing entries — must be identical; a
        // trace cannot tell which backend captured it.
        assert_eq!(full_trace, fast_trace, "{design:?}: captured traces differ");
        assert!(full_trace.expect.timing_recorded);
        // And the canonical text forms are byte-identical.
        assert_eq!(full_trace.to_text(), fast_trace.to_text(), "{design:?}");
    }
}

#[test]
fn full_captured_trace_replays_under_every_backend() {
    let sc = Scenario::single(
        "fb-replay",
        cfg(Design::Medusa, SimBackend::full()),
        zoo::gemm_mlp(),
    );
    let (_, trace) = workload::run_scenario_captured(&sc).unwrap();
    for backend in [
        SimBackend::full(),
        SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
        SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
        SimBackend::fast(),
    ] {
        // verify_replay asserts every recorded exact counter, every
        // timing entry, and the three cycle clocks.
        RunOptions::new()
            .backend(backend)
            .verify_replay(&trace)
            .unwrap_or_else(|e| panic!("replay under {backend:?}: {e:#}"));
    }
}

#[test]
fn multi_tenant_and_staggered_scenarios_survive_the_fast_backend() {
    for name in ["multi-tenant-mix", "staggered-gemm"] {
        let mut full_sc = Scenario::builtin(name).unwrap();
        full_sc.cfg.sim = SimBackend::full();
        let mut fast_sc = full_sc.clone();
        fast_sc.cfg.sim = SimBackend::fast();
        let full = workload::run_scenario(&full_sc).unwrap();
        let fast = workload::run_scenario(&fast_sc).unwrap();
        assert_stats_exact(&full, &fast, name);
        // The stagger really is preserved: tenant 1's busy window still
        // fits after its start offset (the scenario_conformance bound).
        if name == "staggered-gemm" {
            let offset = fast_sc.tenants[1].start_cycle;
            let busy = fast.tenants[1].report.total_cycles();
            assert!(busy + offset <= fast.fabric_cycles, "leap overran the stagger");
        }
    }
}

#[test]
fn golden_traces_replay_under_the_fast_backend() {
    // The checked-in goldens are the long-lived oracle; the fast
    // backend must reproduce whatever they record (all movement
    // counters always; cycles too once timing is recorded).
    for file in ["micro_baseline.trace", "micro_medusa.trace"] {
        let path = ["golden", "rust/golden"]
            .iter()
            .map(|b| std::path::Path::new(b).join(file))
            .find(|p| p.exists())
            .unwrap_or_else(|| panic!("golden trace {file} not found"));
        let trace = medusa::sim::trace::ScenarioTrace::from_file(&path).unwrap();
        RunOptions::new()
            .backend(SimBackend::fast())
            .verify_replay(&trace)
            .unwrap_or_else(|e| panic!("{file} under fast backend: {e:#}"));
    }
}

#[test]
fn explorer_smoke_grid_pareto_output_is_byte_identical_across_backends() {
    let space = DesignSpace::smoke();
    let workers = 4;
    let full = RunOptions::new()
        .threads(workers)
        .backend(SimBackend::full())
        .run_search(&space, &Strategy::Grid, 1, None)
        .expect("full-backend explore");
    let fast = RunOptions::new()
        .threads(workers)
        .backend(SimBackend::fast())
        .run_search(&space, &Strategy::Grid, 1, None)
        .expect("fast-backend explore");
    assert_eq!(full.evaluated, fast.evaluated, "evaluated sets differ across backends");
    let fi: Vec<usize> = full.frontier.iter().map(|e| e.index).collect();
    let fa: Vec<usize> = fast.frontier.iter().map(|e| e.index).collect();
    assert_eq!(fi, fa, "Pareto frontiers differ across backends");
    // Byte-identical rendered artifacts — what the CI step diffs.
    assert_eq!(
        bench_json(&full, &space, "grid", &[]),
        bench_json(&fast, &space, "grid", &[]),
        "Pareto JSON differs across backends"
    );
    assert_eq!(
        full_table(&full).to_csv(),
        full_table(&fast).to_csv(),
        "evaluated-set CSV differs across backends"
    );
}
