//! Conformance suite for the overload-robust serving layer (PR 10):
//! bounded admission, request deadlines, and deterministic
//! retry/backoff must be **bit-exact** replicas of themselves under
//! every execution strategy, alone and composed with the PR 6 fault
//! campaigns.
//!
//! What it locks down, per ISSUE 10's acceptance criteria:
//!
//! * every zoo serving scenario — including the oversubscribed
//!   `serving-overload` builtin — reports identical shed / timed-out /
//!   retried / failed counts across all four backend combinations
//!   (full/elided x stepwise/leap) and across sequential vs parallel
//!   matrix execution;
//! * the oversubscribed builtin actually trips the overload machinery
//!   (nonzero sheds, every arrival resolved exactly once);
//! * a wedged tenant under `policy=degrade` hands its in-flight batch
//!   to the retry layer: with budget the requests re-queue
//!   (`serving.requests_retried`), without budget they fail for good
//!   (`serving.requests_failed`) — backend-invariantly either way;
//! * captured overload traces record the new `serving.*` header keys
//!   and replay bit-exactly under every backend.

use medusa::config::{EdgeMode, PayloadMode, SimBackend};
use medusa::run::RunOptions;
use medusa::serving::ServingSpec;
use medusa::sim::stats::{Counter, SampleId};
use medusa::sim::trace::ScenarioTrace;
use medusa::workload::{self, Scenario, ScenarioOutcome};

const SERVING_SCENARIOS: [&str; 2] = ["serving-poisson", "serving-overload"];

fn backends() -> [SimBackend; 4] {
    [
        SimBackend::full(),
        SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
        SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
        SimBackend::fast(),
    ]
}

/// Everything the overload layer observes: the per-tenant report
/// (which now carries shed / timed-out / retried / failed) and the
/// full serving counter/sample surface including the PR 10 additions.
fn assert_overload_exact(a: &ScenarioOutcome, b: &ScenarioOutcome, what: &str) {
    assert_eq!(a.fabric_cycles, b.fabric_cycles, "{what}: fabric_cycles");
    assert_eq!(a.now_ps, b.now_ps, "{what}: now_ps");
    let (ra, rb) = (a.serving.as_ref().unwrap(), b.serving.as_ref().unwrap());
    assert_eq!(ra.tenants.len(), rb.tenants.len(), "{what}: tenant count");
    for (t, (ta, tb)) in ra.tenants.iter().zip(rb.tenants.iter()).enumerate() {
        assert_eq!(ta, tb, "{what}: tenant {t} serving report");
    }
    for id in [
        Counter::ServingBatches,
        Counter::ServingRequestsArrived,
        Counter::ServingRequestsCompleted,
        Counter::ServingRequestsFailed,
        Counter::ServingRequestsRetried,
        Counter::ServingRequestsShed,
        Counter::ServingRequestsTimedOut,
        Counter::ServingSloMet,
    ] {
        assert_eq!(a.stats.count(id), b.stats.count(id), "{what}: counter {}", id.name());
    }
    for id in [
        SampleId::ServingBatchOccupancy,
        SampleId::ServingLatencyCycles,
        SampleId::ServingQueueDepth,
        SampleId::ServingRetryBackoffCycles,
    ] {
        let (sa, sb) = (a.stats.series_of(id), b.stats.series_of(id));
        assert_eq!(
            (sa.min, sa.max, sa.sum, sa.count),
            (sb.min, sb.max, sb.sum, sb.count),
            "{what}: series {}",
            id.name()
        );
    }
}

#[test]
fn overload_scenarios_are_bit_identical_across_all_backends() {
    for which in SERVING_SCENARIOS {
        let reference = {
            let sc = Scenario::builtin(which).unwrap();
            RunOptions::new().backend(SimBackend::full()).run(&sc).unwrap()
        };
        for backend in backends() {
            let sc = Scenario::builtin(which).unwrap();
            let out = RunOptions::new().backend(backend).run(&sc).unwrap();
            assert_overload_exact(&reference, &out, &format!("{which} on {backend:?}"));
            if backend.payload == PayloadMode::Full {
                assert_eq!(
                    reference.fingerprint(),
                    out.fingerprint(),
                    "{which} on {backend:?}: fingerprint"
                );
            }
        }
    }
}

#[test]
fn overload_matrix_rows_are_bit_identical_sequential_vs_parallel() {
    // The overload counters feed the outcome fingerprint, so matrix
    // bit-equality across worker counts covers shed / timed-out /
    // retried / failed bookkeeping too.
    let seq = RunOptions::new().threads(1).sweep().unwrap();
    let par = RunOptions::new().threads(4).sweep().unwrap();
    let rows = |pts: &[medusa::eval::scenarios::ScenarioPoint]| -> Vec<(&'static str, medusa::interconnect::Design, u64)> {
        pts.iter()
            .filter(|p| SERVING_SCENARIOS.contains(&p.scenario))
            .map(|p| (p.scenario, p.design, p.fingerprint))
            .collect()
    };
    let (s, p) = (rows(&seq), rows(&par));
    assert_eq!(s.len(), 4, "each serving scenario must appear on both matrix designs");
    assert_eq!(s, p, "serving matrix rows diverged between worker counts");
}

#[test]
fn oversubscribed_builtin_trips_the_overload_machinery() {
    let sc = Scenario::builtin("serving-overload").unwrap();
    let out = RunOptions::new().backend(SimBackend::full()).run(&sc).unwrap();
    assert!(out.all_verified(), "shedding load must not corrupt the passes that do run");
    let t0 = &out.serving.as_ref().unwrap().tenants[0];
    assert_eq!(t0.arrived, 12, "the 12-request burst is materialized up front");
    // The burst lands while the first batch's pass is running: 10
    // requests contend for a 3-deep queue, so drop-oldest must shed
    // exactly 7 whatever the design's pass latency.
    assert_eq!(t0.shed, 7, "cap-3 queue under a 12-request burst sheds 7");
    // No faults: the retry budget is armed but never drawn on.
    assert_eq!((t0.retried, t0.failed), (0, 0), "retries need a failed-fast batch");
    // Conservation: every arrival resolves exactly once.
    assert_eq!(
        t0.completed + t0.shed + t0.timed_out,
        12,
        "every request must complete, shed, or time out"
    );
    // The report and the raw counters are the same bookkeeping.
    assert_eq!(out.stats.get("serving.requests_shed"), t0.shed as u64);
    assert_eq!(out.stats.get("serving.requests_timed_out"), t0.timed_out as u64);
    assert_eq!(out.stats.get("serving.requests_failed"), 0);
}

#[test]
fn degraded_batch_requeues_through_the_retry_budget() {
    // serving-overload arms retries=2. Wedge the tenant at cycle 64:
    // the first batch (2 requests, dispatched at cycle 101) stalls,
    // the watchdog degrades the tenant, and fail-fast hands both
    // requests to the retry layer — budget left, so they re-queue and
    // count in `serving.requests_retried`, never in failed.
    let mut sc = Scenario::builtin("serving-overload").unwrap();
    sc.faults =
        medusa::fault::FaultSpec::parse_cli("wedge=0@64,watchdog=512,policy=degrade,seed=11")
            .unwrap();
    let full = RunOptions::new().backend(SimBackend::full()).run(&sc).unwrap();
    let t0 = &full.serving.as_ref().unwrap().tenants[0];
    assert_eq!(t0.completed, 0, "wedged at cycle 64: nothing may complete");
    assert_eq!(t0.shed, 7, "admission bookkeeping is independent of the wedge");
    assert!(t0.retried >= 2, "the failed-fast batch must schedule retries, got {}", t0.retried);
    assert_eq!(t0.failed, 0, "budget of 2 is never exhausted on a quiesced tenant");
    assert!(
        full.stats.series("serving.retry_backoff_cycles").unwrap().count >= 2,
        "each retry must record its pre-drawn backoff delay"
    );
    assert!(!full.all_verified(), "the degraded tenant cannot verify");
    // And the whole composition stays backend-invariant.
    let fast = RunOptions::new().backend(SimBackend::fast()).run(&sc).unwrap();
    assert_overload_exact(&full, &fast, "retried batch under fast backend");
}

#[test]
fn degraded_batch_without_budget_fails_for_good() {
    // Same wedge, retries disarmed: the failed-fast batch has no
    // budget, so both requests count in `serving.requests_failed` on
    // the spot.
    let mut sc = Scenario::builtin("serving-overload").unwrap();
    sc.serving = ServingSpec { retries: 0, backoff: 0, ..sc.serving.clone() };
    sc.faults =
        medusa::fault::FaultSpec::parse_cli("wedge=0@64,watchdog=512,policy=degrade,seed=11")
            .unwrap();
    let full = RunOptions::new().backend(SimBackend::full()).run(&sc).unwrap();
    let t0 = &full.serving.as_ref().unwrap().tenants[0];
    assert_eq!(t0.completed, 0);
    assert_eq!(t0.failed, 2, "the 2-request batch fails for good without a retry budget");
    assert_eq!(t0.retried, 0);
    assert_eq!(full.stats.get("serving.requests_failed"), 2);
    let fast = RunOptions::new().backend(SimBackend::fast()).run(&sc).unwrap();
    assert_overload_exact(&full, &fast, "failed batch under fast backend");
}

#[test]
fn captured_overload_trace_records_new_keys_and_replays_everywhere() {
    let sc = Scenario::builtin("serving-overload").unwrap();
    let (out, trace) = workload::run_scenario_captured(&sc).unwrap();
    assert_eq!(trace.header.serving, sc.serving, "header must record the overload spec");
    let text = trace.to_text();
    for key in [
        "serving.queue_cap = 3",
        "serving.overload = \"drop-oldest\"",
        "serving.deadline = 30000",
        "serving.retries = 2",
        "serving.backoff = 1500",
    ] {
        assert!(text.contains(key), "{key:?} missing from trace text:\n{text}");
    }
    let parsed = ScenarioTrace::from_str(&text).unwrap();
    assert_eq!(parsed, trace, "overload trace text round-trip");
    assert_eq!(parsed.header.serving, sc.serving, "defaults must restore exactly on parse");
    for backend in backends() {
        let replayed = RunOptions::new()
            .backend(backend)
            .verify_replay(&parsed)
            .unwrap_or_else(|e| panic!("overload replay under {backend:?}: {e:#}"));
        assert_overload_exact(&out, &replayed, &format!("replay {backend:?}"));
    }
}

#[test]
fn pre_overload_specs_emit_no_new_header_keys() {
    // The format-regression half: a serving spec that sets none of the
    // PR 10 knobs must capture a header byte-identical to what PR 7
    // produced — no queue_cap / overload / deadline / retries keys.
    let sc = Scenario::builtin("serving-poisson").unwrap();
    let (_, trace) = workload::run_scenario_captured(&sc).unwrap();
    let text = trace.to_text();
    for key in ["serving.queue_cap", "serving.overload", "serving.deadline", "serving.retries", "serving.backoff"]
    {
        assert!(!text.contains(key), "{key} leaked into a pre-overload trace:\n{text}");
    }
    let parsed = ScenarioTrace::from_str(&text).unwrap();
    assert_eq!(parsed.header.serving, sc.serving, "defaults restore to the disabled knobs");
}
