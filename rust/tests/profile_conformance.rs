//! Conformance suite for the zero-perturbation observability layer
//! (PR 9): a run with `--profile` enabled must be **bit-identical** —
//! stats, cycles, traces, fingerprints, serving reports — to the same
//! run with it disabled, on every zoo scenario under every backend
//! combination. Same discipline as the elided-vs-full and
//! leap-vs-stepwise suites: profiling is an observer, never an actor.
//!
//! What it locks down, per ISSUE 9's acceptance criteria:
//!
//! * profile-on vs profile-off: identical fingerprints, counters,
//!   sample series, cycle clocks, and per-port waits on every zoo
//!   scenario × all four backends;
//! * captured traces cannot tell whether the capturing run was
//!   profiled, and a profiled replay reproduces the trace's expect
//!   block bit-for-bit;
//! * the cycle-attribution invariants hold exactly: per clock domain,
//!   `stepped + leapt` equals the domain's total elapsed cycles (three
//!   domains on the hierarchical family); refusal reasons sum to
//!   `attempts - taken`; cap sources sum to `taken`; stepwise backends
//!   never attempt;
//! * utilization windows are internally consistent (busy counts bounded
//!   by window edges, total window edges equal to stepped fabric
//!   edges) and host-time spans cover the four run phases;
//! * the explorer's per-point telemetry marks cold evaluations as
//!   computed and warm-cache re-runs as hits without changing the
//!   evaluated set.

use medusa::config::{EdgeMode, PayloadMode, SimBackend, SystemConfig};
use medusa::interconnect::hierarchical::HierConfig;
use medusa::interconnect::Design;
use medusa::obs::DEFAULT_WINDOW;
use medusa::run::RunOptions;
use medusa::sim::stats::{Counter, SampleId};
use medusa::types::Geometry;
use medusa::workload::{zoo, Scenario, ScenarioOutcome};

/// Same N = 8 geometry as the fast-backend and hierarchical suites:
/// irrational 225/200 MHz clock pair, DDR3 timing on, so the profiled
/// runs exercise the same edge interleaving those suites pin down.
fn cfg(design: Design, sim: SimBackend) -> SystemConfig {
    SystemConfig {
        design,
        geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
        dotprod_units: 16,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: Some(225.0),
        ddr3_timing: true,
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 7,
        sim,
    }
}

fn backends() -> [SimBackend; 4] {
    [
        SimBackend::full(),
        SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
        SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
        SimBackend::fast(),
    ]
}

/// A three-clock-domain family member (fabric + mem + trunk), for the
/// N-domain attribution tests.
fn hier() -> Design {
    Design::Hierarchical(HierConfig { levels: 2, cluster_ports: 4, bypass_ports: 0, trunk_mhz: 300 })
}

/// Every observable the zero-perturbation contract covers. Same checks
/// as the fast-backend suite, but here both sides ran the SAME backend
/// — only the profiling flag differs — so the full fingerprint
/// (feature maps included) must match too; callers assert it.
fn assert_stats_exact(a: &ScenarioOutcome, b: &ScenarioOutcome, what: &str) {
    assert_eq!(a.fabric_cycles, b.fabric_cycles, "{what}: fabric_cycles");
    assert_eq!(a.mem_cycles, b.mem_cycles, "{what}: mem_cycles");
    assert_eq!(a.now_ps, b.now_ps, "{what}: now_ps");
    for &id in Counter::ALL.iter() {
        assert_eq!(a.stats.count(id), b.stats.count(id), "{what}: counter {}", id.name());
    }
    for &id in SampleId::ALL.iter() {
        let (sa, sb) = (a.stats.series_of(id), b.stats.series_of(id));
        assert_eq!(
            (sa.min, sa.max, sa.sum, sa.count),
            (sb.min, sb.max, sb.sum, sb.count),
            "{what}: series {}",
            id.name()
        );
    }
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (t, (ta, tb)) in a.tenants.iter().zip(b.tenants.iter()).enumerate() {
        assert_eq!(ta.read_waits, tb.read_waits, "{what}: tenant {t} read waits");
        assert_eq!(ta.write_waits, tb.write_waits, "{what}: tenant {t} write waits");
    }
    assert_eq!(a.serving, b.serving, "{what}: serving report");
}

/// Run `sc` twice on `backend` — profiling off, then on — and return
/// both outcomes after the bit-identity checks.
fn run_pair(sc: &Scenario, backend: SimBackend, what: &str) -> (ScenarioOutcome, ScenarioOutcome) {
    let off = RunOptions::new()
        .backend(backend)
        .run(sc)
        .unwrap_or_else(|e| panic!("{what}: unprofiled run: {e:#}"));
    let on = RunOptions::new()
        .backend(backend)
        .profile(DEFAULT_WINDOW)
        .run(sc)
        .unwrap_or_else(|e| panic!("{what}: profiled run: {e:#}"));
    assert!(off.profile.is_none(), "{what}: unprofiled run grew a profile");
    assert!(on.profile.is_some(), "{what}: profiled run lost its profile");
    assert_eq!(off.fingerprint(), on.fingerprint(), "{what}: profiling perturbed the run");
    assert_stats_exact(&off, &on, what);
    (off, on)
}

#[test]
fn profiling_is_invisible_on_every_zoo_scenario_and_backend() {
    for net in zoo::all() {
        for backend in backends() {
            let sc = Scenario::single(
                &format!("prof-{}", net.name),
                cfg(Design::Medusa, backend),
                net.clone(),
            );
            let what = format!("{} {backend:?}", net.name);
            let (_, on) = run_pair(&sc, backend, &what);
            let p = on.profile.unwrap();
            // Two clock domains on the flat family, attribution exact.
            assert_eq!(p.sys.domains.len(), 2, "{what}");
            assert_eq!(p.sys.domains[0].total(), on.fabric_cycles, "{what}: fabric edges");
            assert_eq!(p.sys.domains[1].total(), on.mem_cycles, "{what}: mem edges");
        }
    }
}

#[test]
fn profiling_is_invisible_on_the_three_domain_family() {
    for backend in backends() {
        let sc = Scenario::single("prof-hier", cfg(hier(), backend), zoo::gemm_mlp());
        let what = format!("hierarchical {backend:?}");
        let (_, on) = run_pair(&sc, backend, &what);
        let p = on.profile.unwrap();
        assert_eq!(p.sys.domains.len(), 3, "{what}: trunk domain missing");
        assert_eq!(p.sys.domains[0].total(), on.fabric_cycles, "{what}: fabric edges");
        assert_eq!(p.sys.domains[1].total(), on.mem_cycles, "{what}: mem edges");
        // The trunk clock ran: its edges are attributed too.
        assert!(p.sys.domains[2].total() > 0, "{what}: trunk never ticked");
    }
}

#[test]
fn captured_traces_cannot_tell_they_were_profiled() {
    for backend in [SimBackend::full(), SimBackend::fast()] {
        let sc = Scenario::single("prof-trace", cfg(Design::Medusa, backend), zoo::gemm_mlp());
        let (_, plain) = RunOptions::new().backend(backend).run_captured(&sc).unwrap();
        let (out, profiled) = RunOptions::new()
            .backend(backend)
            .profile(DEFAULT_WINDOW)
            .run_captured(&sc)
            .unwrap();
        assert!(out.profile.is_some());
        assert_eq!(plain, profiled, "{backend:?}: captured traces differ");
        assert_eq!(plain.to_text(), profiled.to_text(), "{backend:?}: trace text differs");
    }
}

#[test]
fn profiled_replay_reproduces_the_expect_block() {
    let sc = Scenario::single(
        "prof-replay",
        cfg(Design::Medusa, SimBackend::full()),
        zoo::gemm_mlp(),
    );
    let (out, trace) = RunOptions::new().run_captured(&sc).unwrap();
    for backend in backends() {
        // verify_replay asserts every recorded counter and clock; a
        // profiled replay must pass the same gate and land on the same
        // fingerprint as an unprofiled one.
        let plain = RunOptions::new()
            .backend(backend)
            .verify_replay(&trace)
            .unwrap_or_else(|e| panic!("plain replay under {backend:?}: {e:#}"));
        let profiled = RunOptions::new()
            .backend(backend)
            .profile(DEFAULT_WINDOW)
            .verify_replay(&trace)
            .unwrap_or_else(|e| panic!("profiled replay under {backend:?}: {e:#}"));
        assert_eq!(plain.fingerprint(), profiled.fingerprint(), "{backend:?}");
        assert_eq!(out.fabric_cycles, profiled.fabric_cycles, "{backend:?}");
        assert!(profiled.profile.is_some(), "{backend:?}: replay lost the profile");
    }
}

#[test]
fn leap_accounting_balances_exactly() {
    for net in zoo::all() {
        // Leap backend: every attempt is either taken (attributed to
        // exactly one cap source) or refused (attributed to exactly
        // one blocking component).
        let sc = Scenario::single(
            &format!("prof-leap-{}", net.name),
            cfg(Design::Medusa, SimBackend::fast()),
            net.clone(),
        );
        let out = RunOptions::new().profile(DEFAULT_WINDOW).run(&sc).unwrap();
        let lt = out.profile.unwrap().sys.leap;
        assert!(lt.attempts > 0, "{}: leap backend never attempted", net.name);
        assert_eq!(lt.attempts, lt.taken + lt.refusal_total(), "{}: refusals", net.name);
        assert_eq!(lt.cap_total(), lt.taken, "{}: cap sources", net.name);

        // Stepwise backend: attempts stay 0 and nothing is leapt, so
        // the attribution invariants hold trivially.
        let sc = Scenario::single(
            &format!("prof-step-{}", net.name),
            cfg(Design::Medusa, SimBackend::full()),
            net.clone(),
        );
        let out = RunOptions::new().profile(DEFAULT_WINDOW).run(&sc).unwrap();
        let p = out.profile.unwrap();
        assert_eq!(p.sys.leap.attempts, 0, "{}: stepwise attempted a leap", net.name);
        for d in &p.sys.domains {
            assert_eq!(d.leapt, 0, "{}: stepwise leapt {} edges on {}", net.name, d.leapt, d.name);
        }
    }
}

#[test]
fn utilization_windows_are_internally_consistent() {
    // Full stepwise backend: every fabric edge is stepped, so the
    // window series covers the whole run densely.
    let sc = Scenario::single(
        "prof-util",
        cfg(Design::Medusa, SimBackend::full()),
        zoo::gemm_mlp(),
    );
    let window = 256;
    let out = RunOptions::new().profile(window).run(&sc).unwrap();
    let p = out.profile.unwrap();
    assert!(!p.sys.utilization.is_empty(), "no utilization windows recorded");
    assert!(p.sys.window >= window, "window can only widen (coarsening)");
    let mut total_edges = 0u64;
    let mut prev_start = None;
    for s in &p.sys.utilization {
        assert!(s.edges > 0 && s.edges <= p.sys.window, "window edge count out of range");
        assert_eq!(s.busy.len(), p.sys.groups, "busy series width != port groups");
        for &b in &s.busy {
            assert!(b <= s.edges, "busy count exceeds window edges");
        }
        if let Some(prev) = prev_start {
            assert!(s.start > prev, "window starts must strictly increase");
        }
        prev_start = Some(s.start);
        total_edges += s.edges;
    }
    // Every stepped fabric edge sampled exactly one window.
    assert_eq!(total_edges, p.sys.domains[0].stepped, "window edges != stepped fabric edges");
    // Something was actually busy at some point — the instrument is
    // wired to live state, not zeros.
    assert!(
        p.sys.utilization.iter().any(|s| s.busy.iter().any(|&b| b > 0)),
        "no busy edges recorded on a working run"
    );
}

#[test]
fn serving_runs_profile_without_perturbation() {
    let sc = Scenario::builtin("serving-poisson").expect("builtin serving scenario");
    for backend in [SimBackend::full(), SimBackend::fast()] {
        let what = format!("serving-poisson {backend:?}");
        let (off, on) = run_pair(&sc, backend, &what);
        assert!(off.serving.is_some(), "{what}: serving report missing");
        // The profiled run additionally carries the queue-depth series
        // (change-driven; a run with any arrivals records at least the
        // first transition).
        let p = on.profile.unwrap();
        assert!(!p.sys.serving_depth.is_empty(), "{what}: no serving depth samples");
        for pair in p.sys.serving_depth.windows(2) {
            assert!(pair[0].0 <= pair[1].0, "{what}: depth series cycle order");
            assert_ne!(pair[0].1, pair[1].1, "{what}: depth series not change-driven");
        }
    }
}

#[test]
fn host_spans_cover_the_run_phases() {
    let sc = Scenario::single(
        "prof-host",
        cfg(Design::Medusa, SimBackend::fast()),
        zoo::gemm_mlp(),
    );
    let out = RunOptions::new().profile(DEFAULT_WINDOW).run(&sc).unwrap();
    let host = out.profile.unwrap().host;
    let phases: Vec<&str> = host.iter().map(|&(p, _)| p).collect();
    assert_eq!(phases, ["build", "precompute", "drive", "report"], "phase order");
    for (phase, s) in &host {
        assert!(s.is_finite() && *s >= 0.0, "{phase}: bad span {s}");
    }
}

#[test]
fn explorer_telemetry_marks_cold_computes_and_warm_hits() {
    use medusa::explore::{DesignSpace, ExploreCache, Strategy};
    let space = DesignSpace::smoke();
    let dir = std::env::temp_dir().join(format!("medusa-prof-conf-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cache.tsv");

    let mut cache = ExploreCache::open(&path);
    let cold = RunOptions::new()
        .threads(2)
        .run_search(&space, &Strategy::Grid, 1, Some(&mut cache))
        .unwrap();
    cache.save().unwrap();
    assert_eq!(cold.timings.len(), cold.evaluated.len(), "cold: timings align");
    assert!(cold.timings.iter().all(|t| !t.cache_hit), "cold: nothing should hit");

    let mut cache = ExploreCache::open(&path);
    let warm = RunOptions::new()
        .threads(2)
        .run_search(&space, &Strategy::Grid, 1, Some(&mut cache))
        .unwrap();
    assert!(warm.timings.iter().all(|t| t.cache_hit && t.eval_s == 0.0), "warm: all hits");
    // Telemetry is an observer here too: the evaluated set is
    // unchanged by cache state.
    assert_eq!(cold.evaluated, warm.evaluated, "telemetry perturbed the search");
    let _ = std::fs::remove_dir_all(&dir);
}
