//! Conformance suite for the hierarchical interconnect family (PR 8):
//! clusters of ports on local Medusa transposers feeding a shared
//! trunk that runs in its own (third) clock domain, with an optional
//! bypass path for trunk-direct tenants.
//!
//! What it locks down, per ISSUE 8's acceptance criteria:
//!
//! * a **three-clock-domain** system (fabric + mem + trunk) runs every
//!   zoo scenario bit-identically across all four backend combinations
//!   (full/elided × stepwise/leap) — the N-domain leap generalization
//!   is exercised end-to-end, not just at the scheduler unit level;
//! * lines really cross the trunk (and the bypass, when configured):
//!   the movement counters prove the third domain is load-bearing, so
//!   a scheduler bug that silently starved the trunk could not pass;
//! * captured traces are backend-invariant and their header records the
//!   full `hierarchical:l…:c…:b…:t…` spec — replay reconstructs the
//!   trunk clock domain from the spec alone, so a trace captured by
//!   the full backend replays under every backend;
//! * the family composes with the PR 6 standard fault campaign and the
//!   PR 7 serving layer without perturbing either contract.

use medusa::config::{EdgeMode, PayloadMode, SimBackend, SystemConfig};
use medusa::fault::FaultSpec;
use medusa::interconnect::hierarchical::HierConfig;
use medusa::interconnect::Design;
use medusa::run::RunOptions;
use medusa::sim::stats::{Counter, SampleId};
use medusa::types::Geometry;
use medusa::workload::{self, zoo, Scenario, ScenarioOutcome};

/// The PR 6 standard campaign, unchanged: composition means the same
/// schedule drives the same stalls on the new family.
const CAMPAIGN: &str = "dram_refresh=64/8,cdc=96/6,slow=128/12,corrupt=7,seed=3";

/// Same N = 8 geometry as the fast-backend suite, so cross-suite
/// numbers are comparable and the 225 / 200 / trunk MHz triple gives
/// three pairwise-interleaving clock domains.
fn cfg(design: Design, sim: SimBackend) -> SystemConfig {
    SystemConfig {
        design,
        geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
        dotprod_units: 16,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: Some(225.0),
        ddr3_timing: true,
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 7,
        sim,
    }
}

/// Two family members chosen to cover both routing paths and both
/// trunk depths on the 8-port geometry:
///
/// * `l2:c4:b0:t300` — two clusters of 4, everything over a one-stage
///   trunk, trunk faster than fabric (300 vs 225 MHz);
/// * `l3:c3:b2:t375` — two clusters of 3 plus two bypass ports, a
///   two-stage trunk, and a trunk period that divides neither the
///   fabric nor the mem period (maximally irregular edge interleave).
fn members() -> [Design; 2] {
    [
        Design::Hierarchical(HierConfig {
            levels: 2,
            cluster_ports: 4,
            bypass_ports: 0,
            trunk_mhz: 300,
        }),
        Design::Hierarchical(HierConfig {
            levels: 3,
            cluster_ports: 3,
            bypass_ports: 2,
            trunk_mhz: 375,
        }),
    ]
}

fn backends() -> [SimBackend; 4] {
    [
        SimBackend::full(),
        SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
        SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
        SimBackend::fast(),
    ]
}

/// The stat surface every backend must preserve bit-exactly (same
/// contract as `fast_backend_conformance`, restated here so this suite
/// stands alone as the hierarchical gate).
fn assert_stats_exact(a: &ScenarioOutcome, b: &ScenarioOutcome, what: &str) {
    assert_eq!(a.fabric_cycles, b.fabric_cycles, "{what}: fabric_cycles");
    assert_eq!(a.mem_cycles, b.mem_cycles, "{what}: mem_cycles");
    assert_eq!(a.now_ps, b.now_ps, "{what}: now_ps");
    for &id in Counter::ALL.iter() {
        assert_eq!(a.stats.count(id), b.stats.count(id), "{what}: counter {}", id.name());
    }
    for &id in SampleId::ALL.iter() {
        let (sa, sb) = (a.stats.series_of(id), b.stats.series_of(id));
        assert_eq!(
            (sa.min, sa.max, sa.sum, sa.count),
            (sb.min, sb.max, sb.sum, sb.count),
            "{what}: series {}",
            id.name()
        );
    }
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (t, (ta, tb)) in a.tenants.iter().zip(b.tenants.iter()).enumerate() {
        assert_eq!(ta.read_waits, tb.read_waits, "{what}: tenant {t} read waits");
        assert_eq!(ta.write_waits, tb.write_waits, "{what}: tenant {t} write waits");
        assert_eq!(
            ta.report.total_cycles(),
            tb.report.total_cycles(),
            "{what}: tenant {t} busy cycles"
        );
        assert_eq!(
            ta.report.total_lines_moved(),
            tb.report.total_lines_moved(),
            "{what}: tenant {t} lines moved"
        );
    }
}

fn run(name: &str, design: Design, net: workload::WorkloadNet, sim: SimBackend) -> ScenarioOutcome {
    let sc = Scenario::single(name, cfg(design, sim), net);
    workload::run_scenario(&sc)
        .unwrap_or_else(|e| panic!("{name} / {design:?} / {sim:?}: {e:#}"))
}

#[test]
fn every_zoo_scenario_is_bit_identical_across_all_backends() {
    for net in zoo::all() {
        for design in members() {
            let full = run(&format!("hc-{}", net.name), design, net.clone(), SimBackend::full());
            assert!(full.all_verified(), "{} on {design:?}: full run must verify", net.name);
            // The trunk is load-bearing on every net: a backend that
            // never fired the third domain would still produce numbers,
            // just with these at zero.
            let moved = full.stats.count(Counter::HierReadLinesOverTrunk)
                + full.stats.count(Counter::HierReadLinesBypassed);
            assert!(moved > 0, "{} on {design:?}: no read lines crossed the hierarchy", net.name);

            let elided = run(
                &format!("hc-{}", net.name),
                design,
                net.clone(),
                SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise },
            );
            assert_stats_exact(&full, &elided, &format!("{} {design:?} elided", net.name));

            let leap = run(
                &format!("hc-{}", net.name),
                design,
                net.clone(),
                SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap },
            );
            // Leap keeps the payload, so the full fingerprint (feature
            // maps included) must survive the three-domain leap.
            assert_eq!(
                full.fingerprint(),
                leap.fingerprint(),
                "{} {design:?}: leap changed the outcome fingerprint",
                net.name
            );
            assert!(leap.all_verified(), "{} {design:?}: leap broke golden checks", net.name);
            assert_stats_exact(&full, &leap, &format!("{} {design:?} leap", net.name));

            let fast = run(&format!("hc-{}", net.name), design, net.clone(), SimBackend::fast());
            assert_stats_exact(&full, &fast, &format!("{} {design:?} fast", net.name));
        }
    }
}

#[test]
fn bypass_and_trunk_routes_split_where_the_config_says() {
    // b0: every line crosses the trunk, nothing can bypass.
    let [all_trunk, with_bypass] = members();
    let full = run("hc-routes", all_trunk, zoo::gemm_mlp(), SimBackend::full());
    assert!(full.stats.count(Counter::HierReadLinesOverTrunk) > 0);
    assert!(full.stats.count(Counter::HierWriteLinesOverTrunk) > 0);
    assert_eq!(full.stats.count(Counter::HierReadLinesBypassed), 0, "b0 cannot bypass");
    assert_eq!(full.stats.count(Counter::HierWriteLinesBypassed), 0, "b0 cannot bypass");
    // b2 on an 8-word line: ports 6 and 7 are trunk-direct, so both
    // routes carry traffic on the same net.
    let full = run("hc-routes", with_bypass, zoo::gemm_mlp(), SimBackend::full());
    assert!(full.stats.count(Counter::HierReadLinesOverTrunk) > 0);
    assert!(full.stats.count(Counter::HierReadLinesBypassed) > 0, "bypass ports saw no reads");
    assert!(full.stats.count(Counter::HierWriteLinesOverTrunk) > 0);
    assert!(full.stats.count(Counter::HierWriteLinesBypassed) > 0, "bypass ports saw no writes");
}

#[test]
fn captured_traces_agree_across_backends_and_record_the_spec() {
    for design in members() {
        let full_sc = Scenario::single("hc-trace", cfg(design, SimBackend::full()), zoo::gemm_mlp());
        let fast_sc = Scenario::single("hc-trace", cfg(design, SimBackend::fast()), zoo::gemm_mlp());
        let (_, full_trace) = workload::run_scenario_captured(&full_sc).unwrap();
        let (_, fast_trace) = workload::run_scenario_captured(&fast_sc).unwrap();
        assert_eq!(full_trace, fast_trace, "{design:?}: captured traces differ");
        assert_eq!(full_trace.to_text(), fast_trace.to_text(), "{design:?}");
        assert!(full_trace.expect.timing_recorded);
        // The header spec is the only carrier of the trunk clock: it
        // must round-trip to the exact design, or replay would rebuild
        // a different third domain and every cycle count would drift.
        assert_eq!(full_trace.header.design, design.spec(), "{design:?}: header spec");
        assert_eq!(
            Design::parse(&full_trace.header.design),
            Some(design),
            "{design:?}: header spec must parse back to the design"
        );
    }
}

#[test]
fn full_captured_trace_replays_under_every_backend() {
    // The spiciest member: three levels, bypass ports, and a trunk
    // period that interleaves irregularly with both other domains.
    let [_, spicy] = members();
    let sc = Scenario::single("hc-replay", cfg(spicy, SimBackend::full()), zoo::gemm_mlp());
    let (_, trace) = workload::run_scenario_captured(&sc).unwrap();
    for backend in backends() {
        RunOptions::new()
            .backend(backend)
            .verify_replay(&trace)
            .unwrap_or_else(|e| panic!("replay under {backend:?}: {e:#}"));
    }
}

#[test]
fn the_standard_fault_campaign_composes_with_the_hierarchy() {
    for design in members() {
        let mut sc = Scenario::single("hc-faults", cfg(design, SimBackend::full()), zoo::gemm_mlp());
        sc.faults = FaultSpec::parse_cli(CAMPAIGN).unwrap();
        let full = workload::run_scenario(&sc).unwrap();
        // Delay faults plus detect-only corruption: the run still
        // verifies, and the campaign really fired.
        assert!(full.all_verified(), "{design:?}: faulted full run must verify");
        let injected: u64 = [
            "fault.dram_refresh_stall_cycles",
            "fault.cdc_stall_cycles",
            "fault.lp_slowdown_cycles",
            "fault.corrupt_injected",
        ]
        .iter()
        .map(|n| full.stats.get(n))
        .sum();
        assert!(injected > 0, "{design:?}: campaign injected nothing");
        for backend in backends() {
            let mut sc =
                Scenario::single("hc-faults", cfg(design, backend), zoo::gemm_mlp());
            sc.faults = FaultSpec::parse_cli(CAMPAIGN).unwrap();
            let out = workload::run_scenario(&sc).unwrap();
            assert_stats_exact(&full, &out, &format!("{design:?} faulted {backend:?}"));
        }
    }
}

#[test]
fn serving_composes_with_the_hierarchy() {
    let [all_trunk, _] = members();
    let mk = |sim: SimBackend| {
        // serving-poisson runs on the same 8-port geometry, so the
        // hierarchical member drops straight in.
        let mut sc = Scenario::builtin("serving-poisson").unwrap();
        sc.cfg.design = all_trunk;
        sc.cfg.sim = sim;
        sc
    };
    let reference = RunOptions::new().run(&mk(SimBackend::full())).unwrap();
    let rep = reference.serving.as_ref().expect("serving report");
    assert_eq!(rep.tenants[0].arrived, 6);
    assert_eq!(rep.tenants[0].completed, 6, "every request must complete over the trunk");
    assert!(reference.stats.count(Counter::HierReadLinesOverTrunk) > 0);
    for backend in backends() {
        let out = RunOptions::new().run(&mk(backend)).unwrap();
        assert_stats_exact(&reference, &out, &format!("serving {backend:?}"));
        let (ra, rb) = (reference.serving.as_ref().unwrap(), out.serving.as_ref().unwrap());
        assert_eq!(ra.tenants, rb.tenants, "serving {backend:?}: tenant serving reports");
        if backend.payload == PayloadMode::Full {
            assert_eq!(reference.fingerprint(), out.fingerprint(), "serving {backend:?}");
        }
    }
}
