//! Allocation audit for the simulation hot loop (PR 1 acceptance
//! criterion): `Scheduler::step` and the structures it hands around must
//! not touch the heap, and small `Line`s must clone without allocating.
//!
//! A counting global allocator wraps the system allocator for this test
//! binary; the audit measures the allocation-count delta across each
//! region. Everything lives in ONE `#[test]` so no sibling test thread
//! can allocate concurrently and pollute the counters.

use std::alloc::{GlobalAlloc, Layout, System as SysAlloc};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        SysAlloc.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        SysAlloc.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        SysAlloc.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

use medusa::sim::{ClockDomain, Scheduler};
use medusa::types::Line;

#[test]
fn hot_loop_performs_no_heap_allocation() {
    // --- 1. Scheduler::step over the paper's two-domain clocking.
    let mut s = Scheduler::new(vec![
        ClockDomain::from_mhz("fabric", 225.0),
        ClockDomain::from_mhz("mem", 200.0),
    ]);
    // Warm up (construction above already allocated the domain Vec).
    for _ in 0..10 {
        s.step();
    }
    let before = alloc_count();
    let mut fired_total = 0u64;
    for _ in 0..100_000 {
        let fired = s.step();
        fired_total += fired.count() as u64;
    }
    let delta = alloc_count() - before;
    assert!(fired_total >= 100_000, "steps must fire domains");
    assert_eq!(delta, 0, "Scheduler::step allocated {delta} times in 100k steps");

    // --- 2. Inline Line clone at the paper-default geometry (32 words).
    let line = Line::from_fn(32, |i| i as u64);
    let before = alloc_count();
    let mut acc = 0u64;
    for _ in 0..10_000 {
        let c = line.clone();
        acc = acc.wrapping_add(c.word(31));
        std::hint::black_box(&c);
    }
    let delta = alloc_count() - before;
    assert_eq!(acc, 31u64.wrapping_mul(10_000));
    assert_eq!(delta, 0, "inline Line clone allocated {delta} times in 10k clones");

    // --- 3. Wide lines (1024-bit region, 64 words) exceed the inline
    // capacity and fall back to the boxed slice — correctness there.
    let wide = Line::from_fn(64, |i| i as u64 * 7);
    let c = wide.clone();
    assert_eq!(c.num_words(), 64);
    assert_eq!(c.word(63), 63 * 7);
    assert_eq!(wide, c);
}
