"""Make `pytest python/tests` work from the repository root (and from
python/): put the python/ directory on sys.path so `compile.*` imports
resolve regardless of the invocation directory."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
