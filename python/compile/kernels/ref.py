"""Pure-jnp reference oracles (L1 correctness ground truth).

Everything operates in the "raw Q8.8" domain: tensors carry the integer
representation of Q8.8 fixed-point values in float64 (exact: products fit
in 2**30, receptive-field sums far below 2**53). This mirrors
rust/src/accel/{quant,golden}.rs bit-for-bit — the cross-language contract
the end-to-end verification depends on.
"""

import jax.lax as lax
import jax.numpy as jnp

FRAC_BITS = 8
SCALE = float(1 << FRAC_BITS)
Q_MIN = -32768.0
Q_MAX = 32767.0


def quantize_f32(x):
    """Float -> raw Q8.8 (round-half-even, saturate)."""
    return jnp.clip(jnp.round(jnp.asarray(x, jnp.float64) * SCALE), Q_MIN, Q_MAX)


def dequantize(q):
    return jnp.asarray(q, jnp.float64) / SCALE


def requantize_acc(acc):
    """Raw Q16.16 accumulator -> raw Q8.8: shift with round-half-even,
    saturate. jnp.round implements ties-to-even, matching the Rust
    `shift_round_half_even`."""
    return jnp.clip(jnp.round(jnp.asarray(acc, jnp.float64) / SCALE), Q_MIN, Q_MAX)


def conv2d_q88_ref(ifmap, weights, bias, *, in_c, in_h, in_w, out_c, k, stride, pad, relu):
    """Reference conv in raw-Q8.8 domain.

    ifmap:   [in_c*in_h*in_w] raw Q8.8 (f64)
    weights: [out_c*in_c*k*k] raw Q8.8
    bias:    [out_c]          raw Q8.8
    returns  [out_c*out_h*out_w] raw Q8.8
    """
    x = jnp.reshape(jnp.asarray(ifmap, jnp.float64), (1, in_c, in_h, in_w))
    w = jnp.reshape(jnp.asarray(weights, jnp.float64), (out_c, in_c, k, k))
    b = jnp.asarray(bias, jnp.float64)
    acc = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    acc = acc + (b * SCALE)[None, :, None, None]  # bias << FRAC_BITS
    out = requantize_acc(acc)
    if relu:
        out = jnp.maximum(out, 0.0)
    return jnp.reshape(out, (-1,))


def transpose_ref(lines):
    """Medusa read-direction transposition oracle (paper Fig 4).

    `lines[x]` is the memory line destined to port x (n words each, the
    single-line-per-port snapshot of Fig 4). The data-transfer job is:
    output bank x must hold exactly lines[x] in word order. The kernel
    under test implements this with the paper's diagonal-read + rotate +
    transposed-store schedule; composed, the schedule must be the
    identity on this layout.
    """
    m = jnp.asarray(lines)
    assert m.ndim == 2 and m.shape[0] == m.shape[1], "one line per port"
    return m


def rotate_left_ref(v, amount):
    """Barrel-rotator oracle: out[j] = v[(j + amount) mod n]."""
    return jnp.roll(jnp.asarray(v), -int(amount), axis=0)
