"""L1 Pallas kernel: the layer processor's compute hot-spot — tiled
vector dot-products (the paper's 32-wide DPUs) as an im2col matmul.

TPU mapping (DESIGN.md §Hardware-Adaptation): patches [P, K] x weights
[K, OC] tiled so each grid step stages a (TP x K) activation tile and a
(K x TOC) weight tile in VMEM and issues an MXU matmul; the BlockSpec
grid expresses the HBM<->VMEM double-buffered streaming the paper's
layer processors do with their ifmap/weight buffers. Arithmetic is in
the raw-Q8.8-in-f64 domain (exact integers), so the artifact is
bit-identical to the Rust golden model after requantization.

interpret=True: CPU-PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: P-tiles sized for the MXU's 128 rows; OC tiles of 16 match
# the small output-channel counts of the workloads (padded as needed).
TILE_P = 128
TILE_OC = 16


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (TP x K) x (K x TOC) tile product, full-K (K fits VMEM for
    conv workloads: K = in_c*k*k <= 576 words)."""
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], precision="highest")


def dotprod_matmul(patches, weights_t, *, interpret=True):
    """[P, K] @ [K, OC] with Pallas tiling; P and OC padded to tiles."""
    p, k = patches.shape
    k2, oc = weights_t.shape
    assert k == k2
    pp = -(-p // TILE_P) * TILE_P
    poc = -(-oc // TILE_OC) * TILE_OC
    a = jnp.zeros((pp, k), patches.dtype).at[:p, :].set(patches)
    b = jnp.zeros((k, poc), weights_t.dtype).at[:, :oc].set(weights_t)
    grid = (pp // TILE_P, poc // TILE_OC)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_P, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, TILE_OC), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TILE_P, TILE_OC), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pp, poc), patches.dtype),
        interpret=interpret,
    )(a, b)
    return out[:p, :oc]


def im2col(x, *, k, stride, pad):
    """[C, H, W] -> patches [OH*OW, C*k*k] with (c, ky, kx) feature order
    (must match rust/src/accel/golden.rs::weight_index)."""
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            sl = xp[:, ky : ky + stride * oh : stride, kx : kx + stride * ow : stride]
            cols.append(sl)  # [C, OH, OW]
    # Stack to [k*k, C, OH, OW] -> reorder to (C, ky*kx) feature order.
    stacked = jnp.stack(cols, axis=0).reshape(k * k, c, oh, ow)
    feat = jnp.transpose(stacked, (1, 0, 2, 3)).reshape(c * k * k, oh * ow)
    return feat.T  # [P, K]


def conv2d_q88_pallas(
    ifmap, weights, bias, *, in_c, in_h, in_w, out_c, k, stride, pad, relu, interpret=True
):
    """Conv layer forward in raw-Q8.8 domain using the Pallas matmul.

    Same signature/contract as ref.conv2d_q88_ref.
    """
    from . import ref

    x = jnp.reshape(jnp.asarray(ifmap, jnp.float64), (in_c, in_h, in_w))
    w = jnp.reshape(jnp.asarray(weights, jnp.float64), (out_c, in_c * k * k))
    b = jnp.asarray(bias, jnp.float64)
    patches = im2col(x, k=k, stride=stride, pad=pad)  # [P, K]
    acc = dotprod_matmul(patches, w.T, interpret=interpret)  # [P, OC]
    acc = acc + (b * ref.SCALE)[None, :]
    out = ref.requantize_acc(acc)
    if relu:
        out = jnp.maximum(out, 0.0)
    oh = (in_h + 2 * pad - k) // stride + 1
    ow = (in_w + 2 * pad - k) // stride + 1
    # [P, OC] -> channel-major [OC, OH, OW] flat (the DRAM layout).
    return jnp.transpose(out.reshape(oh * ow, out_c), (1, 0)).reshape(-1)
