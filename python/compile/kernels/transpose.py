"""L1 Pallas kernel: the Medusa transposition schedule (paper §III-A, Fig 4).

TPU mapping of the paper's insight (DESIGN.md §Hardware-Adaptation): the
input buffer is a VMEM-resident [N, N] word tile laid out bank-major
(entry [y, x] = word index y of the line destined to port x — exactly the
paper's "words destined to port i are stored at address i of each input
buffer bank"). Each of the N schedule steps performs

  1. a diagonal read   v[k] = in[k, (k - c) mod N]
  2. a barrel rotation rot = roll(v, -c)          (the VPU cross-lane
     shuffle standing in for the Fig 5 barrel shifter)
  3. a transposed store out[j, (j + c) mod N] = rot[j]

so after N steps the output tile is port-major: out[x] = the words of
port x's line in index order. The schedule composes to a transpose of
the input tile; ref.transpose_ref is the oracle.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(in_ref, out_ref, *, n):
    """One pallas program: run the full N-cycle transposition schedule."""
    idx = jnp.arange(n)

    def cycle(c, acc):
        # 1. Diagonal read: v[k] = in[k, (k - c) mod n].
        v = in_ref[idx, (idx - c) % n]
        # 2. Rotation unit: left-rotate by c (out[j] = v[(j + c) mod n]).
        rot = jnp.roll(v, -c)
        # 3. Transposed store: out[j, (j + c) mod n] = rot[j], expressed
        #    as accumulation with the cycle's permutation matrix (each
        #    output bank is written exactly once per cycle).
        perm = (idx[None, :] == ((idx[:, None] + c) % n)).astype(acc.dtype)
        return acc + rot[:, None] * perm

    acc = jax.lax.fori_loop(0, n, cycle, jnp.zeros((n, n), in_ref.dtype))
    out_ref[...] = acc


def medusa_transpose(lines_bank_major, *, n=None, interpret=True):
    """Run the transposition kernel on an [N, N] bank-major word tile.

    Returns the port-major tile: row x = the word stream port x receives.
    """
    m = jnp.asarray(lines_bank_major)
    assert m.ndim == 2 and m.shape[0] == m.shape[1]
    n = n or m.shape[0]
    kernel = functools.partial(_transpose_kernel, n=n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), m.dtype),
        interpret=interpret,
    )(m)


def lines_to_bank_major(lines):
    """Pack per-port lines [port, word] into the paper's input-buffer
    layout [bank, port]: entry [y, x] = lines[x, y]."""
    return jnp.asarray(lines).T
