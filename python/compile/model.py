"""L2: the layer processor's computation as a JAX model (build-time only).

Each tiny-VGG layer becomes one jitted function over flat raw-Q8.8
tensors, its hot loop implemented by the L1 Pallas dot-product kernel
(kernels/conv_dotprod.py). `aot.py` lowers each to HLO text for the Rust
runtime; Python never runs at inference time.

The layer list below MUST mirror rust/src/accel/dnn.rs::Network::tiny_vgg
— the artifact names and shapes are the cross-language contract.
"""

import dataclasses
import functools

import jax

jax.config.update("jax_enable_x64", True)  # raw-Q8.8 integers ride in f64

import jax.numpy as jnp  # noqa: E402

from .kernels import conv_dotprod, ref  # noqa: E402


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    name: str
    in_c: int
    in_h: int
    in_w: int
    out_c: int
    k: int
    stride: int
    pad: int
    relu: bool

    @property
    def out_h(self):
        return (self.in_h + 2 * self.pad - self.k) // self.stride + 1

    @property
    def out_w(self):
        return (self.in_w + 2 * self.pad - self.k) // self.stride + 1

    @property
    def ifmap_words(self):
        return self.in_c * self.in_h * self.in_w

    @property
    def weight_count(self):
        return self.out_c * self.in_c * self.k * self.k

    @property
    def ofmap_words(self):
        return self.out_c * self.out_h * self.out_w


def _conv(name, in_c, in_hw, out_c, *, stride=1):
    return LayerSpec(name, in_c, in_hw, in_hw, out_c, 3, stride, 1, True)


# Mirror of Network::tiny_vgg (rust/src/accel/dnn.rs).
TINY_VGG = [
    _conv("conv1", 3, 32, 16),
    _conv("conv2", 16, 32, 16),
    _conv("down1", 16, 32, 32, stride=2),
    _conv("conv3", 32, 16, 32),
    _conv("down2", 32, 16, 64, stride=2),
    _conv("conv4", 64, 8, 64),
]

# A small extra shape used by the quickstart example and kernel tests.
QUICKSTART = LayerSpec("quickstart", 2, 8, 8, 4, 3, 1, 1, True)

ALL_LAYERS = TINY_VGG + [QUICKSTART]


def layer_forward(spec: LayerSpec, use_pallas=True):
    """Build the jittable forward fn for one layer.

    Signature: (ifmap[f64 N], weights[f64 M], bias[f64 out_c]) ->
    (ofmap[f64 P],) — a 1-tuple, lowered with return_tuple=True so the
    Rust side unwraps with to_tuple1/decompose.
    """
    kw = dict(
        in_c=spec.in_c,
        in_h=spec.in_h,
        in_w=spec.in_w,
        out_c=spec.out_c,
        k=spec.k,
        stride=spec.stride,
        pad=spec.pad,
        relu=spec.relu,
    )
    impl = conv_dotprod.conv2d_q88_pallas if use_pallas else ref.conv2d_q88_ref

    def fwd(ifmap, weights, bias):
        return (impl(ifmap, weights, bias, **kw),)

    return fwd


def layer_example_args(spec: LayerSpec):
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((spec.ifmap_words,), f64),
        jax.ShapeDtypeStruct((spec.weight_count,), f64),
        jax.ShapeDtypeStruct((spec.out_c,), f64),
    )


@functools.lru_cache(maxsize=None)
def spec_by_name(name: str) -> LayerSpec:
    for s in ALL_LAYERS:
        if s.name == name:
            return s
    raise KeyError(name)


def transpose_forward(n: int):
    """The Medusa transposition kernel as an exported computation
    (kind=transpose artifact; the quickstart demo runs it via PJRT)."""
    from .kernels import transpose

    def fwd(tile):
        return (transpose.medusa_transpose(tile, n=n),)

    return fwd


def transpose_example_args(n: int):
    return (jax.ShapeDtypeStruct((n, n), jnp.float64),)
