"""AOT lowering: JAX model -> HLO text artifacts + manifest.

Run once at build time (`make artifacts`); the Rust binary is
self-contained afterwards.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import pathlib
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Width of the transposition demo tile (the paper's representative
# W_line/W_acc = 512/16 = 32).
TRANSPOSE_N = 32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifacts(out_dir: pathlib.Path, use_pallas=True, verbose=True):
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_lines = [
        "# name kind in_c in_h in_w out_c k stride pad relu path",
    ]
    for spec in model.ALL_LAYERS:
        fwd = model.layer_forward(spec, use_pallas=use_pallas)
        lowered = jax.jit(fwd).lower(*model.layer_example_args(spec))
        text = to_hlo_text(lowered)
        fname = f"{spec.name}.hlo.txt"
        (out_dir / fname).write_text(text)
        manifest_lines.append(
            f"{spec.name} conv {spec.in_c} {spec.in_h} {spec.in_w} "
            f"{spec.out_c} {spec.k} {spec.stride} {spec.pad} "
            f"{1 if spec.relu else 0} {fname}"
        )
        if verbose:
            print(f"  {spec.name}: {len(text)} chars -> {fname}")

    # The Medusa transposition kernel as its own artifact.
    n = TRANSPOSE_N
    fwd = model.transpose_forward(n)
    lowered = jax.jit(fwd).lower(*model.transpose_example_args(n))
    text = to_hlo_text(lowered)
    fname = "medusa_transpose.hlo.txt"
    (out_dir / fname).write_text(text)
    manifest_lines.append(f"medusa_transpose transpose {n} {n} 0 {n} 0 0 0 0 {fname}")
    if verbose:
        print(f"  medusa_transpose: {len(text)} chars -> {fname}")

    (out_dir / "manifest.txt").write_text("\n".join(manifest_lines) + "\n")
    if verbose:
        print(f"wrote {len(manifest_lines) - 1} artifacts to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the pure-jnp reference instead of the Pallas kernels (debugging)",
    )
    args = ap.parse_args()
    build_artifacts(pathlib.Path(args.out_dir), use_pallas=not args.no_pallas)
    return 0


if __name__ == "__main__":
    sys.exit(main())
