"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes and data; everything is exact-integer (raw Q8.8
in f64), so comparisons are strict equality.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import conv_dotprod, ref, transpose

# ---------------------------------------------------------------------------
# Transposition kernel (the Medusa schedule)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32])
def test_transpose_identity_on_port_layout(n):
    rng = np.random.default_rng(n)
    lines = rng.integers(0, 1 << 16, size=(n, n)).astype(np.float64)
    tile = transpose.lines_to_bank_major(lines)
    out = transpose.medusa_transpose(tile, n=n)
    np.testing.assert_array_equal(np.asarray(out), ref.transpose_ref(lines))


def test_transpose_fig4_example():
    # Paper Fig 4: N=4. Word (x, y) encoded as 16*x + y. After
    # transposition, port x's row must be its line's words in order.
    n = 4
    lines = np.array([[16 * x + y for y in range(n)] for x in range(n)], dtype=np.float64)
    out = transpose.medusa_transpose(transpose.lines_to_bank_major(lines), n=n)
    np.testing.assert_array_equal(np.asarray(out), lines)


@given(
    n=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_transpose_random_data_exact(n, seed):
    rng = np.random.default_rng(seed)
    lines = rng.integers(-(1 << 15), 1 << 15, size=(n, n)).astype(np.float64)
    out = transpose.medusa_transpose(transpose.lines_to_bank_major(lines), n=n)
    np.testing.assert_array_equal(np.asarray(out), lines)


@pytest.mark.parametrize("amount", range(8))
def test_rotator_oracle(amount):
    v = jnp.arange(8.0)
    out = ref.rotate_left_ref(v, amount)
    expect = [(j + amount) % 8 for j in range(8)]
    np.testing.assert_array_equal(np.asarray(out), expect)


# ---------------------------------------------------------------------------
# Dot-product (conv) kernel


def rand_layer_data(rng, in_c, in_h, in_w, out_c, k):
    ifmap = rng.integers(-(1 << 11), 1 << 11, size=in_c * in_h * in_w).astype(np.float64)
    weights = rng.integers(-(1 << 7), 1 << 7, size=out_c * in_c * k * k).astype(np.float64)
    bias = rng.integers(-(1 << 7), 1 << 7, size=out_c).astype(np.float64)
    return ifmap, weights, bias


CONV_SHAPES = [
    dict(in_c=1, in_h=4, in_w=4, out_c=1, k=1, stride=1, pad=0, relu=False),
    dict(in_c=2, in_h=8, in_w=8, out_c=4, k=3, stride=1, pad=1, relu=True),
    dict(in_c=3, in_h=6, in_w=6, out_c=5, k=3, stride=2, pad=1, relu=True),
    dict(in_c=4, in_h=5, in_w=7, out_c=2, k=3, stride=1, pad=0, relu=False),
]


@pytest.mark.parametrize("shape", CONV_SHAPES)
def test_conv_pallas_matches_ref(shape):
    rng = np.random.default_rng(42)
    ifmap, weights, bias = rand_layer_data(
        rng, shape["in_c"], shape["in_h"], shape["in_w"], shape["out_c"], shape["k"]
    )
    got = conv_dotprod.conv2d_q88_pallas(ifmap, weights, bias, **shape)
    want = ref.conv2d_q88_ref(ifmap, weights, bias, **shape)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    in_c=st.integers(1, 4),
    hw=st.integers(3, 10),
    out_c=st.integers(1, 6),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_conv_pallas_matches_ref_hypothesis(in_c, hw, out_c, k, stride, relu, seed):
    pad = k // 2
    rng = np.random.default_rng(seed)
    ifmap, weights, bias = rand_layer_data(rng, in_c, hw, hw, out_c, k)
    kw = dict(in_c=in_c, in_h=hw, in_w=hw, out_c=out_c, k=k, stride=stride, pad=pad, relu=relu)
    got = conv_dotprod.conv2d_q88_pallas(ifmap, weights, bias, **kw)
    want = ref.conv2d_q88_ref(ifmap, weights, bias, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_saturation_behaviour():
    # Saturating requantization: huge accumulators clamp to i16 range.
    shape = dict(in_c=1, in_h=3, in_w=3, out_c=1, k=3, stride=1, pad=0, relu=False)
    ifmap = np.full(9, 32767.0)
    weights = np.full(9, 32767.0)
    bias = np.zeros(1)
    got = np.asarray(conv_dotprod.conv2d_q88_pallas(ifmap, weights, bias, **shape))
    assert got.shape == (1,)
    assert got[0] == 32767.0


def test_im2col_feature_order_matches_weight_layout():
    # Feature order must be (c, ky, kx) — the rust weight_index layout.
    x = jnp.arange(2 * 3 * 3, dtype=jnp.float64).reshape(2, 3, 3)
    patches = conv_dotprod.im2col(x, k=3, stride=1, pad=0)
    assert patches.shape == (1, 18)
    expect = np.concatenate([np.asarray(x[0]).ravel(), np.asarray(x[1]).ravel()])
    np.testing.assert_array_equal(np.asarray(patches[0]), expect)


# ---------------------------------------------------------------------------
# Quantization helpers


def test_quantize_round_half_even():
    vals = jnp.asarray([0.5 / 256, 1.5 / 256, -0.5 / 256, -1.5 / 256])
    q = ref.quantize_f32(vals)
    np.testing.assert_array_equal(np.asarray(q), [0.0, 2.0, 0.0, -2.0])


def test_quantize_saturates():
    q = ref.quantize_f32(jnp.asarray([1e6, -1e6]))
    np.testing.assert_array_equal(np.asarray(q), [32767.0, -32768.0])


def test_requantize_matches_rust_semantics():
    # acc = 384 (1.5 LSB) -> 2; acc = 128 (0.5 LSB) -> 0; -128 -> 0;
    # -384 -> -2 (ties to even) — mirrors quant.rs tests.
    acc = jnp.asarray([384.0, 128.0, -128.0, -384.0])
    np.testing.assert_array_equal(np.asarray(ref.requantize_acc(acc)), [2.0, 0.0, -0.0, -2.0])
