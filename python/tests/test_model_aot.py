"""L2 model + AOT pipeline tests: layer list mirrors the Rust network,
every layer lowers to parseable HLO text, and pallas/reference paths
agree on real layer shapes.
"""

import pathlib
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from compile import aot, model


def test_tiny_vgg_mirrors_rust_network():
    # Shapes chain: out of layer i == in of layer i+1.
    specs = model.TINY_VGG
    assert [s.name for s in specs] == ["conv1", "conv2", "down1", "conv3", "down2", "conv4"]
    for a, b in zip(specs, specs[1:]):
        assert a.out_c == b.in_c, f"{a.name} -> {b.name}"
        assert a.out_h == b.in_h and a.out_w == b.in_w, f"{a.name} -> {b.name}"
    # Anchor a couple of absolute shapes (mirrors dnn.rs tests).
    assert specs[0].ifmap_words == 3 * 32 * 32
    assert specs[2].out_h == 16  # stride-2 downsample
    assert specs[-1].ofmap_words == 64 * 8 * 8


@pytest.mark.parametrize("spec", model.TINY_VGG, ids=lambda s: s.name)
def test_layer_pallas_matches_reference(spec):
    rng = np.random.default_rng(hash(spec.name) % 2**31)
    ifmap = rng.integers(-(1 << 11), 1 << 11, size=spec.ifmap_words).astype(np.float64)
    weights = rng.integers(-(1 << 7), 1 << 7, size=spec.weight_count).astype(np.float64)
    bias = rng.integers(-(1 << 7), 1 << 7, size=spec.out_c).astype(np.float64)
    got = model.layer_forward(spec, use_pallas=True)(ifmap, weights, bias)[0]
    want = model.layer_forward(spec, use_pallas=False)(ifmap, weights, bias)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lowering_produces_hlo_text():
    spec = model.QUICKSTART
    lowered = jax.jit(model.layer_forward(spec)).lower(*model.layer_example_args(spec))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f64" in text
    # No Mosaic custom-call may survive: interpret=True keeps it plain HLO.
    assert "tpu_custom_call" not in text


def test_build_artifacts_roundtrip(tmp_path=None):
    out = pathlib.Path(tempfile.mkdtemp(prefix="medusa_aot_test_"))
    aot.build_artifacts(out, verbose=False)
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    entries = [l for l in manifest if not l.startswith("#")]
    assert len(entries) == len(model.ALL_LAYERS) + 1  # + transpose
    names = {l.split()[0] for l in entries}
    assert {"conv1", "conv2", "down1", "conv3", "down2", "conv4", "quickstart",
            "medusa_transpose"} <= names
    for line in entries:
        path = out / line.split()[-1]
        assert path.is_file()
        head = path.read_text()[:200]
        assert head.startswith("HloModule"), f"{path} is not HLO text"
    # Executable end-to-end on the local CPU backend: compile one module
    # back and run it (sanity that the text is self-contained).
    from jax._src.lib import xla_client as xc

    backend = xc.make_cpu_client()
    hlo = (out / "quickstart.hlo.txt").read_text()
    comp = xc.XlaComputation(
        xc._xla.hlo_module_from_text(hlo).as_serialized_hlo_module_proto()
    ) if hasattr(xc._xla, "hlo_module_from_text") else None
    # Fall back: at minimum the text parsed above; execution is covered by
    # the Rust runtime integration test.
    del backend, comp
    # Cleanup
    for p in out.iterdir():
        p.unlink()
    out.rmdir()


def test_example_args_match_specs():
    for spec in model.ALL_LAYERS:
        args = model.layer_example_args(spec)
        assert args[0].shape == (spec.ifmap_words,)
        assert args[1].shape == (spec.weight_count,)
        assert args[2].shape == (spec.out_c,)
