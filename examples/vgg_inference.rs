//! **End-to-end validation driver** (the repository's headline example,
//! recorded in EXPERIMENTS.md): full tiny-VGG inference through the
//! simulated accelerator at the paper's representative design point.
//!
//! Every tensor byte travels through the interconnect under test; the
//! conv math executes via the AOT-compiled JAX/Pallas artifacts on PJRT
//! (golden fallback if artifacts are missing); each layer's output is
//! verified bit-for-bit against the Q8.8 golden model AND against what
//! actually landed in simulated DRAM. Both interconnects run at the
//! fabric clock the P&R model says they close at (Fig 6), so the final
//! comparison shows the *system-level* consequence of the paper's
//! frequency results.
//!
//! Run with: `cargo run --release --example vgg_inference`

use medusa::accel::dnn::Network;
use medusa::accel::quant::Fixed16;
use medusa::config::SystemConfig;
use medusa::coordinator::{ComputeBackend, InferenceDriver};
use medusa::interconnect::Design;
use medusa::runtime::ConvExecutor;
use medusa::types::Geometry;
use medusa::util::Prng;

fn backend() -> ComputeBackend {
    match ConvExecutor::new() {
        Ok(exec) => {
            println!("compute backend: PJRT (AOT JAX/Pallas artifacts)");
            ComputeBackend::Pjrt(Box::new(exec))
        }
        Err(e) => {
            println!("compute backend: golden (artifacts unavailable: {e})");
            ComputeBackend::Golden
        }
    }
}

fn main() -> anyhow::Result<()> {
    let net = Network::tiny_vgg();
    let input: Vec<Fixed16> = {
        let mut p = Prng::new(0xda7a);
        (0..net.layers[0].ifmap_words())
            .map(|_| Fixed16::from_f32((p.f64() as f32) * 2.0 - 1.0))
            .collect()
    };
    println!(
        "workload: {} — {} layers, {:.1} MMACs, {} input words\n",
        net.name,
        net.layers.len(),
        net.total_macs() as f64 / 1e6,
        input.len()
    );

    let mut results = Vec::new();
    for design in [Design::Medusa, Design::Baseline] {
        let cfg = SystemConfig {
            design,
            geometry: Geometry::paper_default(),
            dotprod_units: 64,
            mem_clock_mhz: 200.0,
            fabric_clock_mhz: None, // P&R timing model decides (Fig 6)
            ddr3_timing: true,
            rotator_stages: 0,
            channel_depths: Default::default(),
            seed: 2024,
            sim: Default::default(),
        };
        // PJRT backend only for the first run to keep runtime modest;
        // data equality across designs is asserted below either way.
        let be = if design == Design::Medusa { backend() } else { ComputeBackend::Golden };
        let mut drv = InferenceDriver::new(cfg, be)?;
        let (report, fm) = drv.run(&net, &input)?;
        println!("{report}");
        anyhow::ensure!(report.all_verified(), "{design:?}: verification failed");
        results.push((design, report, fm));
    }

    let (m, b) = (&results[0], &results[1]);
    anyhow::ensure!(m.2 == b.2, "final feature maps must match across interconnects (§III-F)");
    let speedup = b.1.total_time_ms() / m.1.total_time_ms();
    println!("== system-level result ==");
    println!(
        "medusa @ {:.0} MHz: {:.3} ms | baseline @ {:.0} MHz: {:.3} ms | speedup {speedup:.2}x",
        m.1.fabric_mhz,
        m.1.total_time_ms(),
        b.1.fabric_mhz,
        b.1.total_time_ms()
    );
    println!(
        "effective DRAM bandwidth: medusa {:.2} GB/s vs baseline {:.2} GB/s (peak 12.8)",
        m.1.effective_bandwidth_gbs(512),
        b.1.effective_bandwidth_gbs(512)
    );
    println!("all layers verified on both interconnects ✓");
    Ok(())
}
