//! Bandwidth stress & interference study: drive the paper-point system
//! with adversarial traffic shapes (single-port bursts, staggered port
//! activation, random arrivals) and report delivered bandwidth and
//! per-port fairness — demonstrating §III-F's no-interference claim and
//! the burst-handling of §III-C under conditions the paper only states
//! qualitatively.
//!
//! Run with: `cargo run --release --example bandwidth_stress`

use medusa::interconnect::harness::gen_lines;
use medusa::interconnect::{build_read_network, Design};
use medusa::sim::Stats;
use medusa::types::{Geometry, TaggedLine};
use medusa::util::Prng;

/// Deliver lines with a given arrival pattern, measure per-port word
/// latency and aggregate throughput.
fn run_pattern(
    design: Design,
    geom: Geometry,
    pattern: &str,
    arrivals: Vec<TaggedLine>,
) -> (f64, u64, u64) {
    let mut net = build_read_network(design, geom);
    let mut stats = Stats::new();
    let total_words = arrivals.len() * geom.words_per_line();
    let mut next = 0usize;
    let mut popped = 0usize;
    let mut cycles = 0u64;
    let mut lat_sum = 0u64;
    let mut lat_max = 0u64;
    let mut deliver_cycle: Vec<u64> = Vec::with_capacity(arrivals.len());
    let mut popped_per_port = vec![0usize; geom.read_ports];
    let words_per_line = geom.words_per_line();
    while popped < total_words {
        net.tick(cycles, &mut stats);
        if next < arrivals.len() && net.mem_can_deliver(arrivals[next].port) {
            net.mem_deliver(arrivals[next].clone());
            deliver_cycle.push(cycles);
            next += 1;
        }
        for p in 0..geom.read_ports {
            if net.port_word_available(p) {
                net.port_take_word(p).unwrap();
                popped += 1;
                popped_per_port[p] += 1;
                // Latency of the word's source line (approx: line index).
                let line_idx = {
                    // words pop in line order per port; map count->line
                    let count = popped_per_port[p] - 1;
                    let mut seen = 0usize;
                    let mut idx = 0usize;
                    for (i, a) in arrivals.iter().enumerate() {
                        if a.port == p {
                            if seen == count / words_per_line {
                                idx = i;
                                break;
                            }
                            seen += 1;
                        }
                    }
                    idx
                };
                if line_idx < deliver_cycle.len() {
                    let lat = cycles - deliver_cycle[line_idx];
                    lat_sum += lat;
                    lat_max = lat_max.max(lat);
                }
            }
        }
        cycles += 1;
        assert!(cycles < 10_000_000, "{pattern}: stalled");
    }
    (arrivals.len() as f64 / cycles as f64, lat_sum / total_words.max(1) as u64, lat_max)
}

fn main() {
    let geom = Geometry::paper_default();
    let n_lines = 1024usize;
    println!("stress patterns at 512b/32r ports, {n_lines} lines each\n");
    println!(
        "{:<26} {:<9} {:>11} {:>10} {:>9}",
        "pattern", "design", "lines/cyc", "avg lat", "max lat"
    );

    for design in [Design::Baseline, Design::Medusa] {
        // 1. Round-robin (the friendly case).
        let rr = gen_lines(&geom, n_lines, 1);
        // 2. Single-port mega-burst: all lines to port 0 (worst case for
        //    even partitioning; throughput is port-limited by design).
        let single: Vec<TaggedLine> = gen_lines(&geom, n_lines, 2)
            .into_iter()
            .map(|mut l| {
                l.port = 0;
                l
            })
            .collect();
        // 3. Random destinations (bursty, uneven).
        let mut prng = Prng::new(3);
        let random: Vec<TaggedLine> = gen_lines(&geom, n_lines, 4)
            .into_iter()
            .map(|mut l| {
                l.port = prng.range(0, geom.read_ports - 1);
                l
            })
            .collect();
        for (name, arr) in [("round-robin", rr), ("single-port-burst", single), ("random-dest", random)]
        {
            let (tput, avg, max) = run_pattern(design, geom, name, arr);
            println!("{:<26} {:<9} {:>11.3} {:>10} {:>9}", name, design.name(), tput, avg, max);
        }
        println!();
    }

    println!("notes:");
    println!(" - round-robin sustains ~1 line/cycle on both designs (full DRAM bandwidth);");
    println!(" - single-port-burst is bounded by one port's 1/N share on both designs —");
    println!("   bandwidth partitioning is static and even, exactly as §III-A specifies;");
    println!(" - medusa's latencies sit ~W_line/W_acc cycles above baseline (§III-E),");
    println!("   constant across patterns: transposition adds latency, never interference.");
}
