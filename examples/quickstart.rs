//! Quickstart: the whole three-layer stack in one small program.
//!
//! 1. Build a small Medusa interconnect and push one burst through it,
//!    watching the transposition deliver each port its own words.
//! 2. Load the AOT-compiled JAX/Pallas conv artifact via PJRT and run a
//!    tiny conv layer, verifying it against the Q8.8 golden model.
//! 3. Run the same layer end-to-end through the simulated system —
//!    DRAM -> interconnect -> compute -> interconnect -> DRAM.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` for steps 2-3's PJRT path; falls back to
//! the golden backend otherwise).

use medusa::accel::dnn::ConvLayer;
use medusa::accel::golden::conv2d_q88;
use medusa::accel::quant::Fixed16;
use medusa::config::SystemConfig;
use medusa::coordinator::{ComputeBackend, InferenceDriver};
use medusa::interconnect::harness::{drive_read, gen_lines};
use medusa::interconnect::{build_read_network, Design};
use medusa::runtime::ConvExecutor;
use medusa::types::Geometry;
use medusa::util::Prng;

fn main() -> anyhow::Result<()> {
    // --- 1. The interconnect itself.
    println!("== 1. Medusa transposition network (64-bit iface, 4 ports) ==");
    let geom = Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 };
    let mut net = build_read_network(Design::Medusa, geom);
    let lines = gen_lines(&geom, 8, 7);
    let (res, streams) = drive_read(net.as_mut(), &lines, true);
    println!(
        "moved {} lines in {} cycles ({:.2} lines/cycle aggregate — full bandwidth)",
        res.lines_moved,
        res.cycles,
        res.lines_per_cycle()
    );
    for (p, s) in streams.iter().enumerate() {
        println!("  port {p} received {} words: {:04x?} ...", s.len(), &s[..4.min(s.len())]);
    }

    // --- 2. The compute artifact via PJRT.
    println!("\n== 2. AOT JAX/Pallas conv via PJRT ==");
    let layer = ConvLayer {
        name: "quickstart",
        in_c: 2,
        in_h: 8,
        in_w: 8,
        out_c: 4,
        k: 3,
        stride: 1,
        pad: 1,
        relu: true,
    };
    let mut prng = Prng::new(1);
    let ifmap: Vec<Fixed16> =
        (0..layer.ifmap_words()).map(|_| Fixed16((prng.next_u64() & 0x7ff) as i16 - 1024)).collect();
    let (weights, bias) = InferenceDriver::gen_weights(&mut prng, &layer);
    let golden = conv2d_q88(&layer, &ifmap, &weights, &bias);
    let backend = match ConvExecutor::new() {
        Ok(mut exec) => {
            let got = exec.run_conv("quickstart", &ifmap, &weights, &bias)?;
            println!(
                "PJRT result == golden model: {} ({} output words)",
                if got == golden { "YES (bit-exact)" } else { "NO" },
                got.len()
            );
            ComputeBackend::Pjrt(Box::new(ConvExecutor::new()?))
        }
        Err(e) => {
            println!("PJRT artifacts unavailable ({e}); falling back to golden backend");
            ComputeBackend::Golden
        }
    };

    // --- 3. End to end through the simulated system.
    println!("\n== 3. One layer end-to-end through the simulated system ==");
    let cfg = SystemConfig {
        design: Design::Medusa,
        geometry: Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 },
        dotprod_units: 8,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: Some(200.0),
        ddr3_timing: true,
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 1,
        sim: Default::default(),
    };
    let mut drv = InferenceDriver::new(cfg, backend)?;
    let region = drv.alloc_and_preload(&ifmap);
    let (report, _of_region, ofmap) = drv.run_layer(&layer, region, &weights, &bias)?;
    println!(
        "layer '{}': load {} cyc, compute {} cyc, drain {} cyc; verified: {}",
        report.layer,
        report.load_cycles,
        report.compute_cycles,
        report.drain_cycles,
        report.verified
    );
    assert!(report.verified);
    assert_eq!(ofmap, golden);
    println!("\nquickstart OK — interconnect, PJRT compute, and system all agree");
    Ok(())
}
