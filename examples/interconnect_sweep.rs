//! Design-space exploration: sweep interconnect geometries and print
//! resource cost, peak frequency, and simulated cycle-efficiency for
//! both designs side by side — the tool a deployer would use to pick an
//! interconnect for their accelerator/board combination.
//!
//! Run with: `cargo run --release --example interconnect_sweep`

use medusa::fpga::timing::peak_frequency;
use medusa::fpga::{DesignPoint, Device};
use medusa::interconnect::harness::{drive_read, gen_lines};
use medusa::interconnect::{build_read_network, Design};
use medusa::types::Geometry;
use medusa::util::next_pow2;

fn main() {
    let dev = Device::virtex7_690t();
    println!(
        "{:>6} {:>7} {:>10} | {:>10} {:>10} {:>6} | {:>10} {:>10} {:>6} | {:>9}",
        "ports", "iface", "burst", "base LUT", "medusa LUT", "save",
        "base MHz", "medusa MHz", "gain", "lines/cyc"
    );
    for ports in [4usize, 8, 12, 16, 20, 24, 32, 48, 64] {
        let w_line = next_pow2(ports * 16);
        let geom = Geometry { w_line, w_acc: 16, read_ports: ports, write_ports: ports, max_burst: 32 };
        let dpus = ports * 2; // keep DSP pressure proportional
        let base = DesignPoint { design: Design::Baseline, geometry: geom, dpus };
        let med = DesignPoint { design: Design::Medusa, geometry: geom, dpus };
        let (bl, ml) = (
            medusa::fpga::resources::baseline_read(&geom).lut
                + medusa::fpga::resources::baseline_write(&geom).lut,
            medusa::fpga::resources::medusa_read(&geom).lut
                + medusa::fpga::resources::medusa_write(&geom).lut,
        );
        let (bf, mf) = (peak_frequency(&base), peak_frequency(&med));
        // Cycle-efficiency of the Medusa read path at this geometry.
        let lines = gen_lines(&geom, 512, 3);
        let mut net = build_read_network(Design::Medusa, geom);
        let (res, _) = drive_read(net.as_mut(), &lines, false);
        println!(
            "{:>6} {:>6}b {:>10} | {:>10} {:>10} {:>5.1}x | {:>10} {:>10} {:>5} | {:>9.3}",
            ports,
            w_line,
            32,
            bl,
            ml,
            bl as f64 / ml as f64,
            bf,
            mf,
            if bf == 0 { "inf".into() } else { format!("{:.2}x", mf as f64 / bf as f64) },
            res.lines_per_cycle()
        );
    }
    println!(
        "\ndevice: {} ({} LUT, {} BRAM-18K, {} DSP)",
        dev.name, dev.luts, dev.bram18, dev.dsps
    );
    println!("savings grow with port count — the paper's §III-D complexity gap in action.");
}
